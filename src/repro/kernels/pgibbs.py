"""Fused particle-Gibbs sweep: every chain x series in ONE time-major scan.

The stochvol cycle's latent-path update was an *opaque* vmapped op — per
chain, per series, an independent :func:`repro.inference.smc.csmc` (its own
forward scan, its own backward ancestry scan, its own per-particle key
splits). This module restructures the sweep so a single ``lax.scan`` over
time advances the whole (K chains, S series, P particles) slab per step,
sharing the AR(1) transition arithmetic (:func:`repro.kernels.ref
.ar1_propagate`) with the adjacent MH rounds' ``gaussian_ar1`` delta kernel.

Two numeric modes:

``mode="compat"``
    Bit-for-bit identical to ``vmap(vmap(csmc))`` (the opaque path): the
    per-series key chains, per-particle proposal keys, and conditional
    multinomial (Gumbel-categorical) resampling draws are reproduced
    exactly — only the loop structure changes. This is the regression
    anchor: the fused layout proves itself against the sequential twin.

``mode="fast"``
    Same conditional-SMC algorithm (slot-0 retained particle, conditional
    multinomial resampling, ancestral trace-back — Andrieu et al. 2010) but
    with slab-granular randomness: ONE normal draw of shape (S, P) per
    chain-step instead of S*P individually-keyed draws behind 2 rounds of
    key splitting, and inverse-CDF multinomial resampling (S*P uniforms +
    a binary search over the P-bin CDF) instead of Gumbel-max (S*P*P
    gumbels). Distributionally identical transitions, different streams —
    validated statistically against the compat mode / conjugate harness
    (tests/test_pgibbs_fused.py), not bitwise.

Pure VPU/scan work — there is no matmul to tile, so this is a fused-scan
kernel rather than a ``pallas_call`` (the Pallas grid machinery would add
per-step launch overhead to what XLA already fuses into one loop body; see
docs/ARCHITECTURE.md "Fused pgibbs dataflow").
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .ref import ar1_propagate, sv_obs_loglik

MODES = ("fast", "compat")


def _take_p(arr: jax.Array, idx: jax.Array) -> jax.Array:
    """Gather along the trailing particle axis: arr (..., P), idx (..., P)
    or (...,) -> same-rank-as-idx result."""
    if idx.ndim == arr.ndim:
        return jnp.take_along_axis(arr, idx, axis=-1)
    return jnp.take_along_axis(arr, idx[..., None], axis=-1)[..., 0]


@functools.partial(jax.jit, static_argnames=("num_particles", "mode", "obs_logpdf"))
def batched_pgibbs_sweep(
    keys: jax.Array,  # (K,) per-chain step keys
    obs: jax.Array,  # (S, T) observed series, shared across chains
    h: jax.Array,  # (K, S, T) retained latent paths (the reference particles)
    phi: jax.Array,  # (K,) AR(1) persistence per chain
    s2: jax.Array,  # (K,) AR(1) innovation variance per chain
    *,
    num_particles: int,
    mode: str = "fast",
    obs_logpdf: Callable | None = None,  # elementwise (x, h) -> log weight
    h0: float = 0.0,
) -> jax.Array:
    """One conditional-SMC sweep for all K chains' S series at once.

    Returns the new retained paths (K, S, T). ``obs_logpdf`` defaults to the
    stochastic-volatility observation factor (:func:`repro.kernels.ref
    .sv_obs_loglik`); any elementwise ``(x, h) -> logp`` works.
    """
    if mode not in MODES:
        raise ValueError(f"unknown pgibbs mode {mode!r}; expected one of {MODES}")
    logpdf = obs_logpdf if obs_logpdf is not None else sv_obs_loglik
    k, s, t_len = h.shape
    p = num_particles
    phi_b = phi[:, None, None]  # broadcast (K, 1, 1) against (K, S, P)
    s2_b = s2[:, None, None]
    xs_t = jnp.moveaxis(obs, -1, 0)  # (T, S)
    href_t = jnp.moveaxis(h, -1, 0)  # (T, K, S)

    if mode == "compat":
        # Reproduce vmap(vmap(csmc)) exactly: a (K, S) lattice of per-series
        # key chains, per-particle proposal keys, Gumbel-categorical
        # multinomial resampling. split/normal/categorical under vmap
        # produce the same bits as the per-series calls they replace.
        series_keys = jax.vmap(lambda ck: jax.random.split(ck, s))(keys)  # (K, S)

        def step(carry, inp):
            h_prev, skeys = carry  # (K, S, P), (K, S) keys
            x_t, h_ref_t = inp  # (S,), (K, S)
            trip = jax.vmap(jax.vmap(lambda kk: jax.random.split(kk, 3)))(skeys)
            skeys_n, k_prop, k_res = trip[..., 0], trip[..., 1], trip[..., 2]
            prop_keys = jax.vmap(jax.vmap(lambda kk: jax.random.split(kk, p)))(
                k_prop
            )  # (K, S, P) keys
            noise = jax.vmap(jax.vmap(jax.vmap(
                lambda kk: jax.random.normal(kk, ())
            )))(prop_keys)
            h_t = ar1_propagate(h_prev, noise, phi_b, s2_b)
            h_t = h_t.at[..., 0].set(h_ref_t)
            logw = logpdf(x_t[None, :, None], h_t)
            anc = jax.vmap(jax.vmap(
                lambda kk, lw: jax.random.categorical(kk, lw, shape=(p,))
            ))(k_res, logw)
            anc = anc.at[..., 0].set(0)
            h_next = _take_p(h_t, anc)
            return (h_next, skeys_n), (h_t, anc, logw)

        h_init = jnp.full((k, s, p), h0, obs.dtype)
        (_, end_keys), (hs, ancs, logws) = jax.lax.scan(
            step, (h_init, series_keys), (xs_t, href_t)
        )
        pick = jax.vmap(jax.vmap(lambda kk: jax.random.split(kk, 2)))(end_keys)
        b_last = jax.vmap(jax.vmap(jax.random.categorical))(
            pick[..., 1], logws[-1]
        )  # (K, S)
    else:
        # fast: slab-granular randomness — one (S, P) normal and one (S, P)
        # uniform block per chain-step, inverse-CDF multinomial resampling.
        def step(carry, inp):
            h_prev, ckeys = carry  # (K, S, P), (K,) keys
            x_t, h_ref_t = inp
            trip = jax.vmap(lambda kk: jax.random.split(kk, 3))(ckeys)
            ckeys_n, k_prop, k_res = trip[:, 0], trip[:, 1], trip[:, 2]
            noise = jax.vmap(lambda kk: jax.random.normal(kk, (s, p)))(k_prop)
            h_t = ar1_propagate(h_prev, noise, phi_b, s2_b)
            h_t = h_t.at[..., 0].set(h_ref_t)
            logw = logpdf(x_t[None, :, None], h_t)
            # conditional multinomial via inverse CDF: O(P log P) per series
            # instead of Gumbel-max's O(P^2); slot 0 stays pinned to the
            # retained lineage.
            cdf = jnp.cumsum(jax.nn.softmax(logw, axis=-1), axis=-1)
            u = jax.vmap(lambda kk: jax.random.uniform(kk, (s, p)))(k_res)
            anc = jax.vmap(jax.vmap(
                lambda c, uu: jnp.searchsorted(c, uu)
            ))(cdf, u).astype(jnp.int32)
            anc = jnp.minimum(anc, p - 1).at[..., 0].set(0)
            h_next = _take_p(h_t, anc)
            return (h_next, ckeys_n), (h_t, anc, logw)

        h_init = jnp.full((k, s, p), h0, obs.dtype)
        (_, end_keys), (hs, ancs, logws) = jax.lax.scan(
            step, (h_init, keys), (xs_t, href_t)
        )
        pick = jax.vmap(lambda kk: jax.random.split(kk, 2))(end_keys)
        b_last = jax.vmap(
            lambda kk, lw: jax.random.categorical(kk, lw, axis=-1)
        )(pick[:, 1], logws[-1])  # (K, S)

    # Shared backward ancestral trace: one scan for the whole (K, S) lattice.
    def back(b, t):
        h_t = _take_p(hs[t], b)
        b_prev = jnp.where(t > 0, _take_p(ancs[t - 1], b), 0)
        return b_prev, h_t

    _, traj_rev = jax.lax.scan(back, b_last, jnp.arange(t_len - 1, -1, -1))
    return jnp.moveaxis(traj_rev[::-1], 0, -1)  # (T, K, S) -> (K, S, T)


def pgibbs_sweep_fused(
    key: jax.Array,
    obs: jax.Array,  # (S, T)
    h: jax.Array,  # (S, T)
    phi: jax.Array,
    s2: jax.Array,
    *,
    num_particles: int,
    mode: str = "fast",
    obs_logpdf: Callable | None = None,
    h0: float = 0.0,
) -> jax.Array:
    """Single-chain wrapper over :func:`batched_pgibbs_sweep` (K = 1).

    Bitwise equal to ``batched_pgibbs_sweep(key[None], ...)[0]`` by
    construction, which is what makes the sequential cycle twin and the
    K-chain ensemble runner bit-for-bit comparable.
    """
    out = batched_pgibbs_sweep(
        key[None], obs, h[None], jnp.asarray(phi)[None], jnp.asarray(s2)[None],
        num_particles=num_particles, mode=mode, obs_logpdf=obs_logpdf, h0=h0,
    )
    return out[0]
