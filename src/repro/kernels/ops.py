"""Jitted public wrappers: pick the Pallas kernel on TPU, interpret-mode
kernel or pure-jnp reference elsewhere."""
from __future__ import annotations

import jax

from . import ref
from .batched_loglik import batched_logit_delta as _batched_logit_delta_kernel
from .fused_ce import fused_ce as _fused_ce_kernel
from .logit_loglik import logit_delta as _logit_delta_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fused_ce(h, table, targets, *, mode: str = "auto", **kw):
    """Per-token log-likelihood over a large vocab.

    mode: "auto" (kernel on TPU, ref elsewhere), "kernel" (force Pallas,
    interpret=True off-TPU), "ref".
    """
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        return ref.fused_ce_ref(h, table, targets)
    interpret = not _on_tpu()
    return _fused_ce_kernel(h, table, targets, interpret=interpret, **kw)


def logit_delta(x, y, w_cur, w_prop, *, mode: str = "auto", **kw):
    """Fused BayesLR pair-evaluation of the MH local-section deltas."""
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        return ref.logit_delta_ref(x, y, w_cur, w_prop)
    interpret = not _on_tpu()
    return _logit_delta_kernel(x, y, w_cur, w_prop, interpret=interpret, **kw)


def batched_logit_delta(xg, yg, w_cur, w_prop, *, mode: str = "auto", **kw):
    """Ensemble-batched (K, m) BayesLR delta block — one call per multi-chain
    sequential-test round."""
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        return ref.batched_logit_delta_ref(xg, yg, w_cur, w_prop)
    interpret = not _on_tpu()
    return _batched_logit_delta_kernel(xg, yg, w_cur, w_prop, interpret=interpret, **kw)
