"""Jitted public wrappers: pick the Pallas kernel on TPU, interpret-mode
kernel or pure-jnp reference elsewhere.

One dispatch vocabulary serves every fused entry point AND the ensemble
engine's ``fused_kernels`` knob:

  ``mode="auto"``    kernel on TPU, reference elsewhere — unless the
                     ``REPRO_FUSED`` environment variable pins a different
                     default (CI sets ``REPRO_FUSED=always`` to exercise the
                     Pallas twins in interpret mode on CPU),
  ``mode="always"``  force the Pallas kernel (interpret=True off-TPU),
  ``mode="never"``   force the pure-jnp reference.

The legacy spellings ``mode="kernel"`` / ``mode="ref"`` are deprecated
aliases for ``always`` / ``never`` and emit a ``DeprecationWarning``.

Orthogonal to dispatch, every wrapper takes a **precision** mode for the
gather/delta data path:

  ``precision="fp32"``  exact float32 end to end — bit-for-bit the
                        pre-precision behaviour, and the tested fallback;
  ``precision="bf16"``  the gathered data slabs (and matmul operands) are
                        cast to bfloat16 before the kernel, halving the
                        bytes the memory-bound delta rounds move; every
                        kernel still *accumulates* in float32
                        (``preferred_element_type``/explicit upcasts), so
                        downstream Welford statistics stay fp32;
  ``precision="auto"``  defers to ``$REPRO_PRECISION``, defaulting to fp32.

Block sizes are consulted from :mod:`repro.kernels.autotune` when tuning is
enabled (explicit ``tile_*`` kwargs always win); ``REPRO_AUTOTUNE=0`` pins
the shipped defaults.
"""
from __future__ import annotations

import os
import warnings

import jax
import jax.numpy as jnp

from . import autotune, ref
from .batched_loglik import batched_logit_delta as _batched_logit_delta_kernel
from .fused_ce import batched_fused_ce as _batched_fused_ce_kernel
from .fused_ce import fused_ce as _fused_ce_kernel
from .gaussian_ar1 import batched_gaussian_ar1_delta as _batched_gaussian_ar1_kernel
from .logit_loglik import logit_delta as _logit_delta_kernel

MODES = ("auto", "always", "never")
_DEPRECATED_ALIASES = {"kernel": "always", "ref": "never"}
ENV_VAR = "REPRO_FUSED"

PRECISIONS = ("auto", "fp32", "bf16")
PRECISION_ENV_VAR = "REPRO_PRECISION"


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def normalize_mode(mode: str) -> str:
    """Canonicalize a dispatch mode, accepting (and warning on) the
    deprecated ``kernel``/``ref`` spellings."""
    if mode in _DEPRECATED_ALIASES:
        canon = _DEPRECATED_ALIASES[mode]
        warnings.warn(
            f"mode={mode!r} is deprecated; use mode={canon!r}",
            DeprecationWarning,
            stacklevel=3,
        )
        return canon
    if mode not in MODES:
        raise ValueError(f"unknown dispatch mode {mode!r}; expected one of {MODES}")
    return mode


def use_kernel(mode: str = "auto") -> bool:
    """Resolve a dispatch mode to "run the Pallas kernel?" — the single
    decision shared by these wrappers and ``ChainEnsemble._use_fused``."""
    mode = normalize_mode(mode)
    if mode == "auto":
        env = os.environ.get(ENV_VAR, "auto")
        mode = normalize_mode(env) if env != "auto" else "auto"
    if mode == "always":
        return True
    if mode == "never":
        return False
    return _on_tpu()


def resolve_precision(precision: str = "auto") -> str:
    """Resolve a precision mode to the concrete ``fp32``/``bf16`` path;
    ``auto`` defers to ``$REPRO_PRECISION`` and defaults to exact fp32."""
    if precision not in PRECISIONS:
        raise ValueError(
            f"unknown precision {precision!r}; expected one of {PRECISIONS}"
        )
    if precision == "auto":
        env = os.environ.get(PRECISION_ENV_VAR, "fp32")
        if env not in ("fp32", "bf16"):
            raise ValueError(
                f"${PRECISION_ENV_VAR}={env!r}; expected 'fp32' or 'bf16'"
            )
        return env
    return precision


def _bf16(*arrays):
    return tuple(a.astype(jnp.bfloat16) for a in arrays)


def _tiles(family: str, shape, kw: dict) -> dict:
    """Autotuned block sizes for the kernel path — explicit tile kwargs win."""
    if any(k.startswith("tile_") for k in kw):
        return kw
    merged = dict(autotune.tiles_for(family, tuple(int(d) for d in shape)))
    merged.update(kw)
    return merged


def dispatch_summary() -> str:
    """One attribution line for example/bench/serve logs: which path the
    auto dispatch takes right now, at what precision, with tuning on/off."""
    path = "pallas" + ("" if _on_tpu() else "-interpret") if use_kernel() else "ref"
    return (
        f"kernels: dispatch={path} ({ENV_VAR}={os.environ.get(ENV_VAR, 'auto')}) "
        f"precision={resolve_precision()} "
        f"autotune={'on' if autotune.enabled() else 'off'} "
        f"backend={jax.default_backend()}"
    )


def fused_ce(h, table, targets, *, mode: str = "auto", precision: str = "auto",
             **kw):
    """Per-token log-likelihood over a large vocab.

    mode: "auto" (kernel on TPU, ref elsewhere), "always" (force Pallas,
    interpret=True off-TPU), "never" (pure-jnp reference).
    """
    if resolve_precision(precision) == "bf16":
        h, table = _bf16(h, table)
    if not use_kernel(mode):
        return ref.fused_ce_ref(h, table, targets)
    kw = _tiles("fused_ce", (h.shape[0], h.shape[1], table.shape[0]), kw)
    return _fused_ce_kernel(h, table, targets, interpret=not _on_tpu(), **kw)


def batched_fused_ce(h, table, targets, *, mode: str = "auto",
                     precision: str = "auto", **kw):
    """Ensemble-batched (K, T) per-token log-likelihood — one call per
    multi-chain round of the LM likelihood (table shared or per-chain)."""
    if resolve_precision(precision) == "bf16":
        h, table = _bf16(h, table)
    if not use_kernel(mode):
        return ref.batched_fused_ce_ref(h, table, targets)
    v = table.shape[0] if table.ndim == 2 else table.shape[1]
    kw = _tiles("batched_fused_ce", h.shape + (v,), kw)
    return _batched_fused_ce_kernel(h, table, targets, interpret=not _on_tpu(), **kw)


def logit_delta(x, y, w_cur, w_prop, *, mode: str = "auto",
                precision: str = "auto", **kw):
    """Fused BayesLR pair-evaluation of the MH local-section deltas."""
    if resolve_precision(precision) == "bf16":
        x, w_cur, w_prop = _bf16(x, w_cur, w_prop)
    if not use_kernel(mode):
        return ref.logit_delta_ref(x, y, w_cur, w_prop)
    kw = _tiles("logit_delta", x.shape, kw)
    return _logit_delta_kernel(x, y, w_cur, w_prop, interpret=not _on_tpu(), **kw)


def batched_logit_delta(xg, yg, w_cur, w_prop, *, mode: str = "auto",
                        precision: str = "auto", **kw):
    """Ensemble-batched (K, m) BayesLR delta block — one call per multi-chain
    sequential-test round."""
    if resolve_precision(precision) == "bf16":
        xg, w_cur, w_prop = _bf16(xg, w_cur, w_prop)
    if not use_kernel(mode):
        return ref.batched_logit_delta_ref(xg, yg, w_cur, w_prop)
    kw = _tiles("batched_loglik", xg.shape, kw)
    return _batched_logit_delta_kernel(xg, yg, w_cur, w_prop, interpret=not _on_tpu(), **kw)


def batched_gaussian_ar1_delta(xt, xp, phi_cur, s2_cur, phi_prop, s2_prop,
                               *, mode: str = "auto", precision: str = "auto",
                               **kw):
    """Ensemble-batched (K, m) AR(1) transition-factor delta block (the
    stochvol sig/phi local sections)."""
    if resolve_precision(precision) == "bf16":
        xt, xp = _bf16(xt, xp)
    if not use_kernel(mode):
        return ref.batched_gaussian_ar1_delta_ref(xt, xp, phi_cur, s2_cur, phi_prop, s2_prop)
    kw = _tiles("gaussian_ar1", xt.shape, kw)
    return _batched_gaussian_ar1_kernel(
        xt, xp, phi_cur, s2_cur, phi_prop, s2_prop, interpret=not _on_tpu(), **kw
    )
