"""Jitted public wrappers: pick the Pallas kernel on TPU, interpret-mode
kernel or pure-jnp reference elsewhere.

One dispatch vocabulary serves every fused entry point AND the ensemble
engine's ``fused_kernels`` knob:

  ``mode="auto"``    kernel on TPU, reference elsewhere — unless the
                     ``REPRO_FUSED`` environment variable pins a different
                     default (CI sets ``REPRO_FUSED=always`` to exercise the
                     Pallas twins in interpret mode on CPU),
  ``mode="always"``  force the Pallas kernel (interpret=True off-TPU),
  ``mode="never"``   force the pure-jnp reference.

The legacy spellings ``mode="kernel"`` / ``mode="ref"`` are deprecated
aliases for ``always`` / ``never`` and emit a ``DeprecationWarning``.
"""
from __future__ import annotations

import os
import warnings

import jax

from . import ref
from .batched_loglik import batched_logit_delta as _batched_logit_delta_kernel
from .fused_ce import batched_fused_ce as _batched_fused_ce_kernel
from .fused_ce import fused_ce as _fused_ce_kernel
from .gaussian_ar1 import batched_gaussian_ar1_delta as _batched_gaussian_ar1_kernel
from .logit_loglik import logit_delta as _logit_delta_kernel

MODES = ("auto", "always", "never")
_DEPRECATED_ALIASES = {"kernel": "always", "ref": "never"}
ENV_VAR = "REPRO_FUSED"


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def normalize_mode(mode: str) -> str:
    """Canonicalize a dispatch mode, accepting (and warning on) the
    deprecated ``kernel``/``ref`` spellings."""
    if mode in _DEPRECATED_ALIASES:
        canon = _DEPRECATED_ALIASES[mode]
        warnings.warn(
            f"mode={mode!r} is deprecated; use mode={canon!r}",
            DeprecationWarning,
            stacklevel=3,
        )
        return canon
    if mode not in MODES:
        raise ValueError(f"unknown dispatch mode {mode!r}; expected one of {MODES}")
    return mode


def use_kernel(mode: str = "auto") -> bool:
    """Resolve a dispatch mode to "run the Pallas kernel?" — the single
    decision shared by these wrappers and ``ChainEnsemble._use_fused``."""
    mode = normalize_mode(mode)
    if mode == "auto":
        env = os.environ.get(ENV_VAR, "auto")
        mode = normalize_mode(env) if env != "auto" else "auto"
    if mode == "always":
        return True
    if mode == "never":
        return False
    return _on_tpu()


def fused_ce(h, table, targets, *, mode: str = "auto", **kw):
    """Per-token log-likelihood over a large vocab.

    mode: "auto" (kernel on TPU, ref elsewhere), "always" (force Pallas,
    interpret=True off-TPU), "never" (pure-jnp reference).
    """
    if not use_kernel(mode):
        return ref.fused_ce_ref(h, table, targets)
    return _fused_ce_kernel(h, table, targets, interpret=not _on_tpu(), **kw)


def batched_fused_ce(h, table, targets, *, mode: str = "auto", **kw):
    """Ensemble-batched (K, T) per-token log-likelihood — one call per
    multi-chain round of the LM likelihood (table shared or per-chain)."""
    if not use_kernel(mode):
        return ref.batched_fused_ce_ref(h, table, targets)
    return _batched_fused_ce_kernel(h, table, targets, interpret=not _on_tpu(), **kw)


def logit_delta(x, y, w_cur, w_prop, *, mode: str = "auto", **kw):
    """Fused BayesLR pair-evaluation of the MH local-section deltas."""
    if not use_kernel(mode):
        return ref.logit_delta_ref(x, y, w_cur, w_prop)
    return _logit_delta_kernel(x, y, w_cur, w_prop, interpret=not _on_tpu(), **kw)


def batched_logit_delta(xg, yg, w_cur, w_prop, *, mode: str = "auto", **kw):
    """Ensemble-batched (K, m) BayesLR delta block — one call per multi-chain
    sequential-test round."""
    if not use_kernel(mode):
        return ref.batched_logit_delta_ref(xg, yg, w_cur, w_prop)
    return _batched_logit_delta_kernel(xg, yg, w_cur, w_prop, interpret=not _on_tpu(), **kw)


def batched_gaussian_ar1_delta(xt, xp, phi_cur, s2_cur, phi_prop, s2_prop,
                               *, mode: str = "auto", **kw):
    """Ensemble-batched (K, m) AR(1) transition-factor delta block (the
    stochvol sig/phi local sections)."""
    if not use_kernel(mode):
        return ref.batched_gaussian_ar1_delta_ref(xt, xp, phi_cur, s2_cur, phi_prop, s2_prop)
    return _batched_gaussian_ar1_kernel(
        xt, xp, phi_cur, s2_cur, phi_prop, s2_prop, interpret=not _on_tpu(), **kw
    )
