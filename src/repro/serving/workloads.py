"""Serving-workload registry: every kernel family serves for free.

A :class:`ServingWorkload` bundles what the pool needs to keep a posterior
resident: a configured :class:`~repro.core.ensemble.ChainEnsemble` (whose
target went through :func:`repro.core.target_builder.build_target`, so the
fused multi-chain kernels ride along wherever dispatch selects them), the
initial parameters, and the workload's request classes
(:class:`~repro.serving.resident.QuerySpec`).

The three paper workloads register through their experiment drivers'
``make_serving_workload()`` entries (lazy imports keep the serving layer
importable without pulling every experiment); the ``ppl`` workload compiles
a probabilistic *program* through :func:`repro.ppl.compile_partitioned_target`
— the end-to-end demonstration that a registered-family program gets the
whole serving stack (resident ensemble, batching, freshness, checkpoints)
without any workload-specific code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.ensemble import ChainEnsemble
from .resident import QuerySpec

Params = Any


@dataclasses.dataclass(frozen=True)
class ServingWorkload:
    """One servable posterior: ensemble + initial point + request classes."""

    name: str
    ensemble: ChainEnsemble
    theta0: Params
    query_specs: dict[str, QuerySpec]
    default_class: str
    description: str = ""

    def __post_init__(self):
        if self.default_class not in self.query_specs:
            raise ValueError(
                f"default_class {self.default_class!r} not in query_specs "
                f"{sorted(self.query_specs)}"
            )


def row_sampler(rows: np.ndarray) -> Callable[[jax.Array, int], np.ndarray]:
    """A ``QuerySpec.make_queries`` that samples request inputs uniformly
    from a host-side pool of rows (the shared idiom of the predictive
    workloads: query points drawn from the held-out set)."""
    rows = np.asarray(rows)

    def make_queries(qkey: jax.Array, n: int) -> np.ndarray:
        idx = np.asarray(jax.random.randint(qkey, (n,), 0, rows.shape[0]))
        return rows[idx]

    return make_queries


_REGISTRY: dict[str, Callable[..., ServingWorkload]] = {}


def register_serving_workload(name: str, builder: Callable[..., ServingWorkload]):
    """Register (or overwrite) a workload builder under ``name``."""
    _REGISTRY[name] = builder
    return builder


def serving_workloads() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def build_serving_workload(name: str, **kw) -> ServingWorkload:
    """Instantiate a registered workload (builders accept ``smoke=`` plus
    size/engine keywords; see each experiment's ``make_serving_workload``)."""
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown serving workload {name!r}; registered: {serving_workloads()}"
        )
    return _REGISTRY[name](**kw)


# ---------------------------------------------------------------------------
# Built-in workloads. The experiment-backed builders import lazily so that
# `import repro.serving` stays cheap and cycle-free.
# ---------------------------------------------------------------------------


def _bayeslr_builder(**kw) -> ServingWorkload:
    from ..experiments import bayeslr

    return bayeslr.make_serving_workload(**kw)


def _stochvol_builder(**kw) -> ServingWorkload:
    from ..experiments import stochvol

    return stochvol.make_serving_workload(**kw)


def _jointdpm_builder(**kw) -> ServingWorkload:
    from ..experiments import jointdpm

    return jointdpm.make_serving_workload(**kw)


def make_ppl_workload(
    *,
    smoke: bool = False,
    num_chains: int = 4,
    n: int | None = None,
    d: int = 3,
    batch_size: int = 50,
    epsilon: float = 0.05,
    sigma: float = 0.08,
    seed: int = 0,
) -> ServingWorkload:
    """Serve a *compiled probabilistic program*: a plated Bernoulli-logit
    regression written against :mod:`repro.ppl`, lowered by
    ``compile_partitioned_target`` (which recognizes the ``logit`` family and
    attaches the fused ensemble kernel), then dropped into a stock
    :class:`~repro.core.ensemble.ChainEnsemble`."""
    from ..core import SubsampledMHConfig
    from ..core.proposals import RandomWalk
    from ..ppl import Trace, compile_partitioned_target, dists

    n = n if n is not None else (300 if smoke else 2000)
    key = jax.random.key(seed)
    kx, ky = jax.random.split(key)
    x = jax.random.normal(kx, (n, d))
    w_true = jnp.linspace(-1.0, 1.0, d)
    yv = jnp.where(
        jax.random.bernoulli(ky, jax.nn.sigmoid(x @ w_true)), 1.0, -1.0
    )
    tr = Trace()
    w = tr.sample(
        "w", dists.mvnormal_diag,
        tr.constant("mu_w", jnp.zeros(d)),
        tr.constant("sig_w", jnp.sqrt(0.1) * jnp.ones(d)),
        value=jnp.zeros(d),
    )
    with tr.plate("data", n):
        xn = tr.constant("x", x)
        z = tr.det("z", lambda xx, ww: xx @ ww, xn, w)
        yn = tr.sample("y", dists.bernoulli_logits, z, value=yv)
        tr.observe(yn, yv)
    target = compile_partitioned_target(tr, w)
    ens = ChainEnsemble(
        target, RandomWalk(sigma), num_chains,
        config=SubsampledMHConfig(batch_size=min(batch_size, n), epsilon=epsilon),
    )
    make_queries = row_sampler(np.asarray(x))
    def _level_sampler(qkey: jax.Array, n_rows: int) -> np.ndarray:
        return np.asarray(
            jax.random.uniform(qkey, (n_rows,), minval=0.05, maxval=0.95)
        )

    specs = {
        "predictive": QuerySpec(
            fn=lambda wd, xs: jax.nn.sigmoid(xs @ wd),
            aggregate="mean",
            make_queries=make_queries,
            name="predictive",
        ),
        # posterior quantiles of the coefficient norm — request rows are
        # quantile levels; the whole (S, mb) -> (mb,) reduction runs on
        # device inside SnapshotEvaluator
        "wnorm_quantile": QuerySpec(
            fn=lambda wd, xs: jnp.broadcast_to(
                jnp.linalg.norm(wd), xs.shape
            ),
            aggregate="quantile",
            make_queries=_level_sampler,
            name="wnorm_quantile",
        ),
    }
    return ServingWorkload(
        name="ppl",
        ensemble=ens,
        theta0=jnp.zeros(d),
        query_specs=specs,
        default_class="predictive",
        description=f"compiled Bernoulli-logit program, N={n}, D={d}",
    )


register_serving_workload("bayeslr", _bayeslr_builder)
register_serving_workload("stochvol", _stochvol_builder)
register_serving_workload("jointdpm", _jointdpm_builder)
register_serving_workload("ppl", make_ppl_workload)
