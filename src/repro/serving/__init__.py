"""Posterior query serving: resident ensembles, batching, SLO freshness.

The serving layer over the multi-chain engine (see docs/ARCHITECTURE.md):

    RequestQueue ─▶ EnsemblePool ─▶ ResidentEnsemble ─▶ Snapshot ─▶ values
     batching       freshness        warm ChainEnsemble   posterior
     deadlines      checkpoints      background refresh   window

Front-end: ``python -m repro.launch.serve --workload bayeslr|stochvol|...``.
"""
from .pool import (
    EnsemblePool,
    FreshnessPolicy,
    ServingConfig,
    snapshot_ess,
    snapshot_rhat,
)
from .queue import Request, RequestQueue
from .resident import QuerySpec, ResidentEnsemble, Snapshot
from .workloads import (
    ServingWorkload,
    build_serving_workload,
    make_ppl_workload,
    register_serving_workload,
    serving_workloads,
)

__all__ = [
    "EnsemblePool",
    "FreshnessPolicy",
    "QuerySpec",
    "Request",
    "RequestQueue",
    "ResidentEnsemble",
    "ServingConfig",
    "ServingWorkload",
    "Snapshot",
    "build_serving_workload",
    "make_ppl_workload",
    "register_serving_workload",
    "serving_workloads",
    "snapshot_ess",
    "snapshot_rhat",
]
