"""Request queue: batching, per-request deadlines, and SLO accounting.

The request path is::

    submit() ──▶ pending queue ──▶ batcher ──▶ EnsemblePool.query ──▶ results
                                   (group by workload × request class,
                                    pin ONE fresh snapshot per batch,
                                    concatenate rows, evaluate once,
                                    split results back per request)

Batching is **result-transparent**: the resident evaluates row-wise
functionals at a fixed micro-batch shape, so a request served inside a
batch returns exactly what it would alone (regression-tested). Every
request carries a deadline; completion records latency, deadline
hit/miss, the staleness of the snapshot that served it, and the batch it
rode in — :meth:`RequestQueue.slo_report` aggregates these into the
per-class :func:`repro.core.stats.slo_summary` tables ``launch/serve.py``
prints.

``drain()`` serves synchronously (deterministic; what tests and the smoke
path use); ``start_worker()`` moves the same loop onto a thread for
always-on serving next to the pool's background refreshes.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time

import numpy as np

from ..core.stats import build_slo_report
from .pool import EnsemblePool

_REQUEST_IDS = itertools.count()


@dataclasses.dataclass
class Request:
    """One posterior query plus its lifecycle/SLO record."""

    workload: str
    query_class: str
    xs: np.ndarray
    deadline_s: float
    submitted_at: float
    id: int = dataclasses.field(default_factory=lambda: next(_REQUEST_IDS))
    # -- filled at completion --
    values: np.ndarray | None = None
    error: str | None = None
    latency_s: float | None = None
    deadline_met: bool | None = None
    staleness_s: float | None = None
    batch_size: int | None = None
    # -- tracing (set by a tracer-enabled queue/router at submit) --
    trace_id: str | None = None
    trace: dict | None = None  # open spans: {"root": ..., "queue": ...}
    done: threading.Event = dataclasses.field(default_factory=threading.Event)

    def result(self, timeout_s: float | None = None) -> np.ndarray:
        if not self.done.wait(timeout=timeout_s):
            raise TimeoutError(f"request {self.id} not served in {timeout_s}s")
        if self.error is not None:
            raise RuntimeError(f"request {self.id} failed: {self.error}")
        return self.values


class RequestQueue:
    """Coalesce requests into batched posterior evaluations on a pool."""

    def __init__(
        self,
        pool: EnsemblePool,
        *,
        max_batch: int | None = None,
        default_deadline_s: float | None = None,
        tracer=None,
    ):
        self.pool = pool
        # Optional repro.obs.trace.Tracer: when set, every request carries
        # a trace (root span at submit, queue_wait until batched, one
        # assembly + device_eval span per batch). Tracing off = zero new
        # work on the request path.
        self.tracer = tracer
        self.max_batch = int(max_batch or pool.config.max_batch)
        self.default_deadline_s = (
            pool.config.default_deadline_s
            if default_deadline_s is None
            else float(default_deadline_s)
        )
        self._pending: list[Request] = []
        self._completed: list[Request] = []
        self._lock = threading.Lock()
        self._arrived = threading.Condition(self._lock)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # -- intake ------------------------------------------------------------

    def submit(
        self,
        workload: str,
        query_class: str,
        xs,
        deadline_s: float | None = None,
    ) -> Request:
        req = Request(
            workload=workload,
            query_class=query_class,
            xs=np.asarray(xs),
            deadline_s=self.default_deadline_s if deadline_s is None else deadline_s,
            submitted_at=time.monotonic(),
        )
        if self.tracer is not None:
            root = self.tracer.new_trace(
                f"request:{workload}.{query_class}", "request",
                workload=workload, query_class=query_class, request_id=req.id,
            )
            queue_span = self.tracer.start(
                root["trace_id"], "queue_wait", "queue_wait",
                parent_id=root["span_id"],
            )
            req.trace_id = root["trace_id"]
            req.trace = {"root": root, "queue": queue_span}
        with self._arrived:
            self._pending.append(req)
            self._arrived.notify()
        return req

    @property
    def pending_count(self) -> int:
        with self._lock:
            return len(self._pending)

    @property
    def completed(self) -> list[Request]:
        with self._lock:
            return list(self._completed)

    # -- batched serving ---------------------------------------------------

    def _take_batch(self) -> list[Request]:
        """Pop up to ``max_batch`` same-(workload, class) requests, oldest
        group head first."""
        with self._lock:
            if not self._pending:
                return []
            head = self._pending[0]
            group_key = (head.workload, head.query_class)
            batch, rest = [], []
            for req in self._pending:
                if (req.workload, req.query_class) == group_key and len(batch) < self.max_batch:
                    batch.append(req)
                else:
                    rest.append(req)
            self._pending = rest
        if self.tracer is not None:
            for req in batch:
                if req.trace and "queue" in req.trace:
                    self.tracer.finish(req.trace.pop("queue"))
        return batch

    def _serve_batch(self, batch: list[Request]) -> None:
        name, qclass = batch[0].workload, batch[0].query_class
        # Batch-level spans hang off the batch head's trace: assembly
        # covers concat + snapshot pinning; the evaluator's device_eval
        # span is adopted after the query returns.
        head = batch[0].trace if self.tracer is not None else None
        asm = None
        sink: list | None = [] if head else None
        try:
            if head:
                asm = self.tracer.start(
                    head["root"]["trace_id"], "batch_assembly", "assembly",
                    parent_id=head["root"]["span_id"], batch_size=len(batch),
                )
            # The concatenate is inside the try: one malformed request (e.g.
            # mismatched row width) must fail its batch, not the serve loop.
            sizes = [req.xs.shape[0] if req.xs.ndim else 1 for req in batch]
            xs = np.concatenate([np.atleast_1d(req.xs) for req in batch], axis=0)
            # One fresh snapshot serves the whole batch (consistent draws).
            snap = self.pool.ensure_fresh(name)
            if asm is not None:
                self.tracer.finish(asm, rows=int(xs.shape[0]))
                asm = None
            values, snap = self.pool.query(
                name, qclass, xs, snapshot=snap, span_sink=sink
            )
        except Exception as e:  # noqa: BLE001 — fail the requests, not the server
            now = time.monotonic()
            if asm is not None:
                self.tracer.finish(asm, error=type(e).__name__)
            for req in batch:
                req.error = f"{type(e).__name__}: {e}"
                req.latency_s = now - req.submitted_at
                req.deadline_met = False
                req.batch_size = len(batch)
                self._finish_trace(req)
                req.done.set()
            with self._lock:
                self._completed.extend(batch)
            return
        if head and sink:
            self.tracer.adopt(sink, head["root"]["trace_id"],
                              parent_id=head["root"]["span_id"])
        now = time.monotonic()
        offset = 0
        for req, size in zip(batch, sizes):
            req.values = values[offset:offset + size]
            offset += size
            req.latency_s = now - req.submitted_at
            req.deadline_met = req.latency_s <= req.deadline_s
            req.staleness_s = snap.staleness_s
            req.batch_size = len(batch)
            self._finish_trace(req)
            req.done.set()
        with self._lock:
            self._completed.extend(batch)

    def _finish_trace(self, req: Request) -> None:
        """Close a completing request's open spans (root + any still-open
        queue_wait, e.g. when the batch failed before _take_batch closed
        it)."""
        if self.tracer is None or not req.trace:
            return
        if "queue" in req.trace:
            self.tracer.finish(req.trace.pop("queue"))
        root = req.trace.pop("root", None)
        if root is not None:
            self.tracer.finish(
                root,
                error=req.error,
                deadline_met=req.deadline_met,
                batch_size=req.batch_size,
            )

    def drain(self) -> list[Request]:
        """Serve every pending request (batched) on the calling thread;
        returns the requests completed by this call, in completion order."""
        served: list[Request] = []
        while True:
            batch = self._take_batch()
            if not batch:
                return served
            self._serve_batch(batch)
            served.extend(batch)

    # -- background worker -------------------------------------------------

    def start_worker(self, max_wait_s: float = 0.005) -> None:
        """Serve continuously on a daemon thread. ``max_wait_s`` is how long
        the batcher lingers for more arrivals once the queue is non-empty —
        the latency/batching trade."""
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                with self._arrived:
                    if not self._pending:
                        self._arrived.wait(timeout=0.05)
                        continue
                if max_wait_s:
                    time.sleep(max_wait_s)  # let a batch accumulate
                self.drain()

        self._thread = threading.Thread(target=loop, name="serve-queue", daemon=True)
        self._thread.start()

    def stop_worker(self, timeout_s: float = 30.0) -> None:
        thread = self._thread
        if thread is None:
            return
        self._stop.set()
        with self._arrived:
            self._arrived.notify_all()
        thread.join(timeout=timeout_s)
        self._thread = None

    # -- SLO accounting ----------------------------------------------------

    def slo_report(self) -> dict:
        """Per-(workload, request-class) latency/deadline/staleness tables
        over everything completed so far, in the unified
        :func:`repro.core.stats.build_slo_report` schema (the queue never
        sheds, so its ``shed`` counters are always zero)."""
        with self._lock:
            done = [r for r in self._completed if r.latency_s is not None]
        return build_slo_report(done).to_dict()
