"""The ensemble pool: configuration, freshness policy, and persistence.

An :class:`EnsemblePool` owns one :class:`~repro.serving.resident.ResidentEnsemble`
per registered workload and stands between requests and residents:

  * every query goes through :meth:`EnsemblePool.query`, which first runs
    the :class:`FreshnessPolicy` — a snapshot is only served if it is young
    enough (``max_staleness_s``), deep enough (``min_draws``), and (when
    configured) mixed enough (``min_ess``, cross-chain Geyer ESS of the
    window); a stale snapshot triggers synchronous refreshes until the
    policy admits one;
  * :meth:`save` / :meth:`restore` persist every resident's sampler state,
    controller, step counter, and posterior window through
    :mod:`repro.checkpoint.manager`, so a restarted pool resumes *warm* —
    no re-burn-in, and its next refresh continues the same key schedule the
    original process was on;
  * :meth:`start` / :meth:`stop` run the residents' background refresh
    threads for always-on serving.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from ..checkpoint import manager as ckpt
from ..core.stats import multichain_ess, split_rhat
from .resident import QuerySpec, ResidentEnsemble, Snapshot
from .workloads import ServingWorkload, build_serving_workload


@dataclasses.dataclass(frozen=True)
class FreshnessPolicy:
    """When is a snapshot servable?

    ``max_staleness_s``: newest draw must be younger than this;
    ``min_draws``: the window must hold at least this many cross-chain
    draws (K × window depth);
    ``min_ess``: optional floor on the window's total effective sample
    size, computed on a scalar functional of the draws (the first
    component of the first leaf);
    ``max_rhat``: optional online-convergence gate — the rolling window's
    cross-chain split-R̂ (:func:`repro.core.stats.split_rhat` on the same
    scalar functional) must sit at or below this before the snapshot
    serves. A window too short to split (fewer than 4 draws per chain)
    counts as stale, so the gate forces refreshes until the resident has
    both depth and mixing.

    Staleness is measured against the last *state change*, not only the
    last draw-refresh: a streaming data append
    (:meth:`ResidentEnsemble.append`) marks the window infinitely stale, so
    the ``max_staleness_s`` gate never serves a pre-append posterior as
    fresh no matter how recently it was refreshed.
    """

    max_staleness_s: float = 30.0
    min_draws: int = 64
    min_ess: float | None = None
    max_rhat: float | None = None

    def stale_reason(self, snap: Snapshot) -> str | None:
        """None if servable, else a human-readable refusal."""
        if snap.draws is None:
            return "no draws yet"
        if snap.num_draws < self.min_draws:
            return f"only {snap.num_draws}/{self.min_draws} draws"
        if snap.staleness_s > self.max_staleness_s:
            return f"stale by {snap.staleness_s:.3f}s > {self.max_staleness_s}s"
        if self.min_ess is not None:
            ess = snapshot_ess(snap)
            if ess < self.min_ess:
                return f"window ESS {ess:.1f} < {self.min_ess}"
        if self.max_rhat is not None:
            rhat = snapshot_rhat(snap)
            if rhat is None:
                return "window too short for split-R-hat (need >= 4 draws/chain)"
            if not rhat <= self.max_rhat:  # NaN R-hat must read as stale
                return f"window R-hat {rhat:.4f} > {self.max_rhat}"
        return None

    def is_fresh(self, snap: Snapshot) -> bool:
        return self.stale_reason(snap) is None


def snapshot_ess(snap: Snapshot) -> float:
    """Total cross-chain ESS of a scalar trace of the window draws."""
    leaf = np.asarray(jax.tree.leaves(snap.draws)[0], np.float64)
    k, w = leaf.shape[:2]
    if w < 4:
        return 0.0
    return multichain_ess(leaf.reshape(k, w, -1)[:, :, 0])


def snapshot_rhat(snap: Snapshot) -> float | None:
    """Rolling-window split-R̂ of the same scalar trace ``snapshot_ess``
    uses (the first component of the first draws leaf), or None when the
    window is too short to split into half-chains."""
    if snap.draws is None:
        return None
    leaf = np.asarray(jax.tree.leaves(snap.draws)[0], np.float64)
    k, w = leaf.shape[:2]
    if w < 4:
        return None
    return float(split_rhat(leaf.reshape(k, w, -1)[:, :, 0]))


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Pool-wide serving knobs (per-workload overrides go through
    ``add_workload(..., **build_kw)``)."""

    num_chains: int = 8
    refresh_steps: int = 32  # transitions per refresh block
    window: int = 64  # posterior draws retained per chain
    micro_batch: int = 64  # request rows per compiled evaluation
    max_batch: int = 16  # requests coalesced into one evaluation
    freshness: FreshnessPolicy = FreshnessPolicy()
    default_deadline_s: float = 1.0
    background_interval_s: float = 0.0  # pause between background refreshes
    max_refreshes_per_query: int = 64  # freshness-loop safety bound
    seed: int = 0


class EnsemblePool:
    """Named resident ensembles behind one freshness-enforcing query API."""

    def __init__(self, config: ServingConfig | None = None):
        self.config = config or ServingConfig()
        self._workloads: dict[str, ServingWorkload] = {}
        self._residents: dict[str, ResidentEnsemble] = {}

    # -- registration ------------------------------------------------------

    def add_workload(
        self, workload: str | ServingWorkload, *, key=None, **build_kw
    ) -> ResidentEnsemble:
        """Build (or adopt) a workload and give it a resident ensemble.

        ``key`` overrides the resident's base chain key (default
        ``jax.random.key(config.seed)``) — the hook the fleet layer uses to
        give each shard of one workload an independent chain trajectory
        over the same data.
        """
        cfg = self.config
        if isinstance(workload, str):
            build_kw.setdefault("num_chains", cfg.num_chains)
            build_kw.setdefault("seed", cfg.seed)
            workload = build_serving_workload(workload, **build_kw)
        name = workload.name
        if name in self._residents:
            raise ValueError(f"workload {name!r} already resident in this pool")
        resident = ResidentEnsemble(
            workload.ensemble,
            workload.theta0,
            key=jax.random.key(cfg.seed) if key is None else key,
            window=cfg.window,
            refresh_steps=cfg.refresh_steps,
            micro_batch=cfg.micro_batch,
            name=name,
        )
        self._workloads[name] = workload
        self._residents[name] = resident
        return resident

    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self._residents))

    def resident(self, name: str) -> ResidentEnsemble:
        return self._residents[name]

    def workload(self, name: str) -> ServingWorkload:
        return self._workloads[name]

    def spec(self, name: str, query_class: str) -> QuerySpec:
        return self._workloads[name].query_specs[query_class]

    # -- freshness ---------------------------------------------------------

    def ensure_fresh(self, name: str) -> Snapshot:
        """Refresh ``name`` until its snapshot passes the freshness policy;
        returns the admitted snapshot."""
        resident = self._residents[name]
        policy = self.config.freshness
        snap = resident.snapshot()
        refreshes = 0
        while not policy.is_fresh(snap):
            if refreshes >= self.config.max_refreshes_per_query:
                raise RuntimeError(
                    f"freshness unreachable for {name!r} after {refreshes} "
                    f"refreshes: {policy.stale_reason(snap)}"
                )
            resident.refresh()
            refreshes += 1
            snap = resident.snapshot()
        return snap

    def warm(self) -> None:
        """Bring every resident to a servable snapshot (initial burn)."""
        for name in self.names():
            self.ensure_fresh(name)

    # -- streaming append --------------------------------------------------

    def append_observations(self, name: str, new_data) -> int:
        """Fold newly appended observations into ``name``'s running chains
        (see :meth:`ResidentEnsemble.append`). The resident's staleness
        clock resets to "never refreshed", so the next freshness-checked
        query refuses the pre-append window and refreshes against the grown
        posterior before serving. Returns the number of sections added."""
        return self._residents[name].append(new_data)

    # -- queries -----------------------------------------------------------

    def query(
        self,
        name: str,
        query_class: str,
        xs,
        *,
        snapshot: Snapshot | None = None,
        span_sink: list | None = None,
    ) -> tuple[np.ndarray, Snapshot]:
        """Freshness-checked posterior-functional evaluation.

        Returns ``(values, snapshot_used)``; pass an explicit ``snapshot``
        (e.g. pinned by the request queue for a whole batch) to skip the
        freshness round-trip. ``span_sink`` collects the evaluator's raw
        ``device_eval`` trace span when the caller is tracing.
        """
        spec = self.spec(name, query_class)
        if snapshot is None:
            snapshot = self.ensure_fresh(name)
        return self._residents[name].query(
            spec, xs, snapshot=snapshot, span_sink=span_sink
        )

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> None:
        for resident in self._residents.values():
            resident.start_background(self.config.background_interval_s)

    def stop(self) -> None:
        for resident in self._residents.values():
            resident.stop_background()

    # -- persistence -------------------------------------------------------

    def save(self, ckpt_dir: str, keep: int = 3) -> str:
        """Atomically persist every resident (state + posterior window)."""
        state = {
            "residents": {
                name: res.state_dict() for name, res in self._residents.items()
            }
        }
        step = max((r.steps_done for r in self._residents.values()), default=0)
        return ckpt.save(ckpt_dir, step, state, keep=keep)

    def restore(self, ckpt_dir: str, step: int | None = None) -> int:
        """Restore residents saved by :meth:`save` into this pool's
        (identically configured) residents. Returns the checkpoint step."""
        step_loaded, flat = ckpt.restore(ckpt_dir, step=step)
        for name, resident in self._residents.items():
            prefix = f"residents__{name}__"
            sub = {
                k[len(prefix):]: v for k, v in flat.items() if k.startswith(prefix)
            }
            if not sub:
                raise KeyError(
                    f"checkpoint {ckpt_dir} has no state for resident {name!r}"
                )
            resident.load_flat(sub)
        return step_loaded

    def slo_snapshot_report(self) -> dict:
        """Per-resident snapshot ages / depths (for dashboards and smoke)."""
        out = {}
        for name in self.names():
            snap = self._residents[name].snapshot()
            out[name] = {
                "staleness_s": snap.staleness_s,
                "num_draws": snap.num_draws,
                "steps_done": snap.steps_done,
                "fresh": self.config.freshness.is_fresh(snap),
            }
        return out
