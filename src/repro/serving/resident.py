"""Resident posterior ensembles: warm sampler state behind a query API.

The paper's pitch is that sublinear per-transition cost makes posterior
inference cheap enough to sit inside an application loop. This module is
the serving half of that claim: a :class:`ResidentEnsemble` keeps a
:class:`repro.core.ensemble.ChainEnsemble` *alive* across requests —
compiled step functions, per-chain sampler states, and (when scheduled)
controller state all stay warm — and interleaves

  * **refresh**: advance every chain a block of transitions on the
    ensemble's resumable :meth:`~repro.core.ensemble.ChainEnsemble.step_keys`
    schedule, appending the collected draws to a rolling per-chain window.
    Chunked refreshes reproduce one offline ``run`` of the same ensemble
    bit for bit (regression-tested in ``tests/test_serving.py``);
  * **snapshot**: the current cross-chain posterior window plus
    :func:`repro.core.stats.ensemble_summary` diagnostics and a staleness
    clock — the unit the freshness policy in :mod:`repro.serving.pool`
    admits or refuses to serve;
  * **query**: evaluate a posterior functional (a :class:`QuerySpec`) over
    the snapshot draws — vmapped over chains × window draws in one jitted
    program, micro-batched over request rows so arbitrarily large request
    batches run at a fixed compiled shape.

Background refresh runs on a daemon thread (`start_background`), so
queries always see *some* recent snapshot instead of waiting on MCMC.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
import time
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import _flatten_names
from ..core.ensemble import ChainEnsemble, EnsembleState
from ..core.stats import ensemble_summary

Params = Any


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    """One posterior-functional request class.

    ``fn(theta_draw, xs) -> (B,)`` scores a *single* posterior draw on B
    request rows; the resident vmaps it over every draw in the snapshot and
    aggregates:

      * ``aggregate="mean"``: the posterior mean of ``fn`` per row — e.g.
        BayesLR predictive probabilities ``E[sigmoid(x·w)]``;
      * ``aggregate="quantile"``: per-row posterior quantiles, where
        ``xs[b]`` is the quantile level for row ``b`` — e.g. stochvol
        stationary-volatility quantiles (``fn`` then typically broadcasts a
        scalar per-draw statistic to ``xs.shape``).

    ``make_queries(key, rows) -> xs`` generates representative request
    inputs (used by the serve front-end, benches, and smoke tests).
    """

    fn: Callable[[Params, jax.Array], jax.Array]
    aggregate: str = "mean"  # "mean" | "quantile"
    make_queries: Callable[[jax.Array, int], np.ndarray] | None = None
    name: str = ""

    def __post_init__(self):
        if self.aggregate not in ("mean", "quantile"):
            raise ValueError(f"unknown aggregate {self.aggregate!r}")


class Snapshot(NamedTuple):
    """An immutable view of a resident ensemble's posterior window."""

    draws: Params  # pytree, leaves (K, W, ...) host arrays
    num_draws: int  # K * W
    steps_done: int  # transitions committed per chain since init/restore
    staleness_s: float  # age of the newest draw at snapshot time
    summary: dict  # ensemble_summary of the last refresh's infos
    created_at: float  # time.monotonic() at construction


def _summarize_infos(infos) -> dict:
    """ensemble_summary over plain or composite (dict-keyed) infos."""
    if infos is None:
        return {}
    if hasattr(infos, "accepted"):
        return ensemble_summary(infos)
    if isinstance(infos, dict):
        return {
            name: ensemble_summary(v)
            for name, v in infos.items()
            if hasattr(v, "accepted")
        }
    return {}


def _window_append(window, block, limit: int):
    """Append a (K, n, ...) block to the (K, W, ...) host window, keep last
    ``limit`` draws per chain."""
    block = jax.tree.map(np.asarray, block)
    if window is None:
        merged = block
    else:
        merged = jax.tree.map(
            lambda a, b: np.concatenate([a, b], axis=1), window, block
        )
    return jax.tree.map(lambda a: a[:, -limit:], merged)


class SnapshotEvaluator:
    """Micro-batched posterior-functional evaluation against snapshots.

    Owns the two caches the query path lives on: per-:class:`QuerySpec`
    jitted evaluators, and a per-snapshot-generation device copy of the
    flattened (S, ...) window so a batch of queries against one snapshot
    uploads the draws once. Rows are processed in fixed ``micro_batch``-row
    chunks (the last chunk padded), so the compiled evaluation shape never
    depends on the request batch — the property that makes queue batching
    result-transparent.

    Shared by :class:`ResidentEnsemble` (writer-side queries) and the
    fleet's read replicas (:mod:`repro.fleet.replica`), which answer from a
    delta-streamed copy of the same window.
    """

    def __init__(self, micro_batch: int = 64):
        if micro_batch < 1:
            raise ValueError(f"micro_batch must be >= 1, got {micro_batch}")
        self.micro_batch = int(micro_batch)
        self._eval_cache: dict[Any, Any] = {}
        self._flat_cache: tuple[Any, Any] | None = None

    def invalidate(self) -> None:
        """Drop the device-side window cache (call when the window is
        replaced out-of-band, e.g. on checkpoint restore or replica resync —
        a stale cache could otherwise collide on the generation key)."""
        self._flat_cache = None

    def _evaluator(self, spec: QuerySpec):
        # Both aggregates reduce over the draw axis on device: only (mb,)
        # per chunk crosses to the host instead of the (S, mb) per-draw
        # matrix — the matrix is memory-bound host work that would otherwise
        # dominate a replica's serve path (for quantiles it was a python
        # loop of np.quantile calls per row on top of the transfer).
        # Per-row results are unchanged by padding or chunking (the compiled
        # reduction shape is fixed at (S, mb), and both reductions are
        # column-independent), so the exact-equality batching contracts hold.
        cache_key = (spec.fn, spec.aggregate)
        fn = self._eval_cache.get(cache_key)
        if fn is None:
            if spec.aggregate == "mean":
                fn = jax.jit(
                    lambda draws, xs: jax.vmap(spec.fn, in_axes=(0, None))(
                        draws, xs
                    ).mean(axis=0)
                )
            else:  # quantile: xs[b] carries the level for row b up front

                def _quantile(draws, xs):
                    per_draw = jax.vmap(spec.fn, in_axes=(0, None))(
                        draws, xs
                    )  # (S, mb)
                    levels = jnp.clip(
                        xs.reshape(xs.shape[0], -1)[:, 0], 0.0, 1.0
                    ).astype(per_draw.dtype)
                    return jax.vmap(jnp.quantile, in_axes=(1, 0))(
                        per_draw, levels
                    )

                fn = jax.jit(_quantile)
            self._eval_cache[cache_key] = fn
        return fn

    def evaluate(self, spec: QuerySpec, snap: Snapshot, xs,
                 span_sink: list | None = None) -> np.ndarray:
        """Evaluate ``spec`` over every draw of ``snap`` on request rows
        ``xs``; returns the aggregated (B,) values.

        ``span_sink``, when given, receives one raw ``device_eval`` trace
        span (a plain dict — no trace_id yet; the caller's Tracer adopts
        it) covering the device-side work: window upload + every
        micro-batched evaluator call. Kept dependency-free on purpose:
        replica worker processes ship these dicts back over the pipe."""
        t_open = time.monotonic()
        xs = np.asarray(xs)
        if xs.ndim == 0:
            xs = xs[None]
        if xs.shape[0] == 0:
            return np.zeros((0,), np.float64)
        gen = (snap.steps_done, snap.num_draws)
        cached = self._flat_cache
        if cached is not None and cached[0] == gen:
            flat = cached[1]
        else:
            flat = jax.tree.map(
                lambda a: jnp.asarray(a.reshape((-1,) + a.shape[2:])), snap.draws
            )  # (S, ...) with S = K * W
            self._flat_cache = (gen, flat)
        evaluator = self._evaluator(spec)
        b, mb = xs.shape[0], self.micro_batch
        vals = []
        for start in range(0, b, mb):
            chunk = xs[start:start + mb]
            pad = mb - chunk.shape[0]
            if pad:
                chunk = np.concatenate([chunk, np.repeat(chunk[-1:], pad, axis=0)])
            v = np.asarray(evaluator(flat, jnp.asarray(chunk)))  # (mb,)
            keep = slice(None, mb - pad) if pad else slice(None)
            vals.append(v[keep])
        out = np.concatenate(vals, axis=0).astype(np.float64)
        if span_sink is not None:
            span_sink.append({
                "trace_id": None,
                "span_id": None,
                "parent_id": None,
                "name": f"device_eval:{spec.name or spec.aggregate}",
                "stage": "device_eval",
                "start_s": t_open,
                "dur_s": time.monotonic() - t_open,
                "pid": os.getpid(),
                "rows": int(b),
                "draws": int(snap.num_draws),
            })
        return out


class ResidentEnsemble:
    """A warm :class:`~repro.core.ensemble.ChainEnsemble` serving queries.

    Thread-safe: refresh (foreground or background) and query/snapshot may
    interleave; state mutation happens under a lock and snapshots are
    immutable once taken.
    """

    def __init__(
        self,
        ensemble: ChainEnsemble,
        theta0: Params,
        *,
        key: jax.Array,
        window: int = 64,
        refresh_steps: int = 32,
        micro_batch: int = 64,
        name: str = "resident",
        batched_theta0: bool = False,
    ):
        if window < 1 or refresh_steps < 1 or micro_batch < 1:
            raise ValueError("window, refresh_steps, micro_batch must be >= 1")
        self.ensemble = ensemble
        self.name = name
        self.window = int(window)
        self.refresh_steps = int(refresh_steps)
        self.micro_batch = int(micro_batch)
        self._base_key = key
        self._state: EnsembleState = ensemble.init(theta0, batched=batched_theta0)
        self._steps_done = 0
        self._draws = None  # pytree of np arrays, leaves (K, W<=window, ...)
        self._last_infos = None
        self._last_refresh: float | None = None
        # _lock guards the committed state (snapshot/query reads, commits);
        # _refresh_lock serializes the *mutators* (refresh, load_flat) so the
        # long MCMC run happens outside _lock and never blocks snapshots.
        self._lock = threading.RLock()
        self._refresh_lock = threading.RLock()
        self._evaluator = SnapshotEvaluator(micro_batch)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        # One-shot jax.profiler capture: arm_profile() points the NEXT
        # refresh at a directory; last_profile_dir records where the
        # capture landed (None until one has happened).
        self._profile_dir: str | None = None
        self.last_profile_dir: str | None = None

    # -- refresh -----------------------------------------------------------

    @property
    def steps_done(self) -> int:
        return self._steps_done

    @property
    def state(self) -> EnsembleState:
        return self._state

    def arm_profile(self, profile_dir: str) -> None:
        """Capture a ``jax.profiler`` trace of the *next* refresh block
        into ``profile_dir`` (one-shot; re-arm for another capture). The
        capture is best-effort: an unavailable or failing profiler leaves
        refresh untouched — what ``serve --profile-dir`` relies on."""
        self._profile_dir = profile_dir

    def _profile_ctx(self):
        """A context manager wrapping one refresh run: the armed one-shot
        ``jax.profiler.trace`` capture, or a no-op. Never raises."""
        profile_dir, self._profile_dir = self._profile_dir, None
        if profile_dir is None:
            return contextlib.nullcontext(), None
        try:
            from jax import profiler as jax_profiler

            return jax_profiler.trace(profile_dir), profile_dir
        except Exception:  # noqa: BLE001 — profiling must never break serving
            return contextlib.nullcontext(), None

    def refresh(self, num_steps: int | None = None) -> int:
        """Advance every chain ``num_steps`` (default ``refresh_steps``)
        transitions and fold the collected draws into the window.

        Runs on the resumable step-key schedule, so any sequence of refresh
        calls equals one offline ``ensemble.run`` over the same total steps
        (same base key) bit for bit.
        """
        n = self.refresh_steps if num_steps is None else int(num_steps)
        if n < 1:
            raise ValueError(f"refresh needs num_steps >= 1, got {n}")
        with self._refresh_lock:
            # Only mutators hold _refresh_lock, so these reads are stable;
            # the expensive run happens with _lock released and snapshots
            # keep serving the previous window meanwhile.
            with self._lock:
                state, steps_done = self._state, self._steps_done
            sk = self.ensemble.step_keys(self._base_key, steps_done, n)
            ctx, profiled = self._profile_ctx()
            try:
                with ctx:
                    state, samples, infos = self.ensemble.run(
                        None, state, n, step_keys=sk
                    )
                    jax.block_until_ready(state.theta)
            except Exception:
                if profiled is None:
                    raise
                # The profiler context itself failed (e.g. a second trace
                # already active): redo the block unprofiled — the capture
                # is best-effort, the refresh is not.
                profiled = None
                state, samples, infos = self.ensemble.run(
                    None, state, n, step_keys=sk
                )
                jax.block_until_ready(state.theta)
            if profiled is not None:
                self.last_profile_dir = profiled
            draws = _window_append(self._draws, samples, self.window)
            last_infos = jax.tree.map(np.asarray, infos)
            with self._lock:
                self._draws = draws
                self._last_infos = last_infos
                self._state = state
                self._steps_done = steps_done + n
                self._last_refresh = time.monotonic()
        return n

    # -- streaming append --------------------------------------------------

    def append(self, new_data) -> int:
        """Fold newly appended observations into the *running* chains.

        The streaming append-only target mode: the ensemble's target is
        rebuilt on ``concat([old, new])`` via its
        :class:`~repro.core.target_builder.TargetSpec` recipe (identical to
        a from-scratch build on the concatenated pool — tested property),
        while ``theta`` and ``steps_done`` carry over, so the next
        :meth:`refresh` continues the *same* resumable step-key schedule
        against the grown posterior — no restart, no re-burn-in from
        ``theta0``. Returns the number of sections added.

        Sampler state and (when scheduled) controller state are shaped by
        ``num_sections``, so they are re-initialized for the grown pool
        (the controller re-adapts over the next refreshes). The pre-append
        window is kept servable but marked infinitely stale
        (``_last_refresh = None``): the freshness policy's
        ``max_staleness_s`` gate then refuses to serve pre-append
        posteriors as fresh until a refresh folds the new data in.

        An empty append is a bit-for-bit no-op: same target object, state,
        window, and staleness clock.
        """
        from ..core.target_builder import append_observations

        with self._refresh_lock:
            if self.ensemble.target is None:
                raise ValueError(
                    f"resident {self.name!r} runs a composite transition "
                    "with no single appendable target"
                )
            new_target = append_observations(self.ensemble.target, new_data)
            if new_target is self.ensemble.target:
                return 0
            added = new_target.num_sections - self.ensemble.target.num_sections
            new_ensemble = dataclasses.replace(self.ensemble, target=new_target)
            with self._lock:
                theta = self._state.theta
            fresh = new_ensemble.init(theta, batched=True)
            jax.block_until_ready(fresh.theta)
            with self._lock:
                self.ensemble = new_ensemble
                self._state = fresh
                self._last_refresh = None  # pre-append window is not fresh
        return int(added)

    # -- snapshots ---------------------------------------------------------

    def snapshot(self) -> Snapshot:
        """The current posterior window (empty draws before any refresh)."""
        with self._lock:
            # Clock read under the lock: a concurrent background refresh
            # advancing _last_refresh must not yield negative staleness.
            now = time.monotonic()
            draws = self._draws  # host arrays, replaced (never mutated) by refresh
            staleness = (
                float("inf") if self._last_refresh is None else now - self._last_refresh
            )
            num = 0
            if draws is not None:
                lead = jax.tree.leaves(draws)[0].shape
                num = int(lead[0] * lead[1])
            return Snapshot(
                draws=draws,
                num_draws=num,
                steps_done=self._steps_done,
                staleness_s=staleness,
                summary=_summarize_infos(self._last_infos),
                created_at=now,
            )

    # -- queries -----------------------------------------------------------

    def query(
        self,
        spec: QuerySpec,
        xs,
        *,
        snapshot: Snapshot | None = None,
        span_sink: list | None = None,
    ) -> tuple[np.ndarray, Snapshot]:
        """Evaluate ``spec`` on request rows ``xs`` against a snapshot.

        Returns ``(values (B,), snapshot_used)``; the evaluation itself is
        the shared :class:`SnapshotEvaluator` (fixed-shape micro-batching,
        per-snapshot device cache). ``span_sink`` collects the raw
        ``device_eval`` trace span when the caller is tracing.
        """
        snap = snapshot if snapshot is not None else self.snapshot()
        if snap.draws is None:
            raise RuntimeError(
                f"resident {self.name!r} has no draws yet; refresh() first "
                "(or serve through EnsemblePool, which enforces freshness)"
            )
        return self._evaluator.evaluate(spec, snap, xs, span_sink=span_sink), snap

    # -- background refresh ------------------------------------------------

    def start_background(self, interval_s: float = 0.0) -> None:
        """Refresh continuously (or every ``interval_s``) on a daemon thread."""
        with self._lock:
            if self._thread is not None:
                return
            self._stop.clear()

            def loop():
                while not self._stop.is_set():
                    self.refresh()
                    if interval_s:
                        self._stop.wait(interval_s)

            self._thread = threading.Thread(
                target=loop, name=f"refresh-{self.name}", daemon=True
            )
            self._thread.start()

    def stop_background(self, timeout_s: float = 30.0) -> None:
        thread = self._thread
        if thread is None:
            return
        self._stop.set()
        thread.join(timeout=timeout_s)
        self._thread = None

    # -- persistence -------------------------------------------------------

    def state_dict(self) -> dict:
        """Host pytree for :mod:`repro.checkpoint.manager` (pure arrays)."""
        with self._lock:
            out = {
                "key_data": np.asarray(jax.random.key_data(self._base_key)),
                "steps_done": np.asarray(self._steps_done, np.int64),
                "theta": jax.tree.map(np.asarray, self._state.theta),
                "sampler": jax.tree.map(np.asarray, self._state.sampler_state),
            }
            if self._state.controller is not None:
                out["controller"] = jax.tree.map(np.asarray, self._state.controller)
            if self._draws is not None:
                out["draws"] = self._draws
            return out

    def load_flat(self, flat: dict) -> None:
        """Restore from the flattened-leaf dict a checkpoint ``restore``
        (without target) yields for this resident's subtree. Rebuilds the
        pytree structure from this resident's own (freshly-initialized)
        state, so only a pool with the same configuration can restore."""
        with self._refresh_lock, self._lock:
            # 0 placeholders keep key_data/steps_done as pytree *leaves*
            # (None would vanish from jax.tree.flatten and desync the names).
            core = {
                "key_data": 0,
                "steps_done": 0,
                "theta": self._state.theta,
                "sampler": self._state.sampler_state,
            }
            if self._state.controller is not None:
                core["controller"] = self._state.controller
            names = _flatten_names(core)
            missing = [n for n in names if n not in flat]
            if missing:
                raise KeyError(
                    f"checkpoint is missing leaves for resident "
                    f"{self.name!r}: {missing[:5]}"
                )
            leaves = [flat[n] for n in names]
            _, treedef = jax.tree.flatten(core)
            core = jax.tree.unflatten(treedef, leaves)
            self._base_key = jax.random.wrap_key_data(
                jnp.asarray(core["key_data"])
            )
            self._steps_done = int(core["steps_done"])
            def put_leaf(a, like):
                a = np.asarray(a)
                want = getattr(like, "shape", None)
                if want is not None and a.shape != tuple(want):
                    raise ValueError(
                        f"checkpoint leaf shape {a.shape} != resident shape "
                        f"{tuple(want)} for {self.name!r} — the pool must be "
                        "configured (num_chains, workload sizes, schedule) "
                        "exactly as when it was saved"
                    )
                return jnp.asarray(a, getattr(like, "dtype", None))

            put = lambda tree, like: jax.tree.map(put_leaf, tree, like)
            self._state = EnsembleState(
                put(core["theta"], self._state.theta),
                put(core["sampler"], self._state.sampler_state),
                None
                if self._state.controller is None
                else put(core["controller"], self._state.controller),
            )
            draw_keys = [k for k in flat if k == "draws" or k.startswith("draws__")]
            if draw_keys:
                tmpl = jax.eval_shape(
                    jax.vmap(self.ensemble.collect or (lambda t: t)),
                    self._state.theta,
                )
                dnames = _flatten_names({"draws": tmpl})
                leaves = [np.asarray(flat[n]) for n in dnames]
                _, dtreedef = jax.tree.flatten({"draws": tmpl})
                self._draws = jax.tree.unflatten(dtreedef, leaves)["draws"]
            self._last_infos = None
            self._last_refresh = None  # unknown age: freshness forces a refresh
            # The restored window replaces whatever was resident; a stale
            # device-side cache could otherwise collide on the
            # (steps_done, num_draws) generation key and serve old draws.
            self._evaluator.invalidate()
