"""Atomic, resharding-on-restore checkpointing."""
from . import manager

__all__ = ["manager"]
