"""Checkpointing: atomic, resharding-on-restore, async-capable.

Layout: <dir>/step_<N>/ containing manifest.json + one raw-bytes blob per
leaf (dtype recorded in the manifest — works for bf16 without numpy dtype
support). Writes go to a tmp dir renamed into place, so a preemption
mid-save never corrupts the latest checkpoint. ``restore`` accepts a target
sharding tree: loading onto a *different* mesh shape (elastic rescale after
losing a slice) is just device_put with the new shardings.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

_SEP = "__"


def _flatten(tree: Any, prefix: str = "") -> dict[str, Any]:
    if isinstance(tree, dict):
        out = {}
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{_SEP}{k}" if prefix else str(k)))
        return out
    if isinstance(tree, (tuple, list)):
        out = {}
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{_SEP}{i}" if prefix else str(i)))
        return out
    return {prefix: tree}


def save(ckpt_dir: str, step: int, state: dict, keep: int = 3) -> str:
    """Write state (a pytree of arrays + scalars) atomically."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    manifest = {"step": step, "leaves": {}}
    for name, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fn = f"{name}.bin"
        with open(os.path.join(tmp, fn), "wb") as f:
            f.write(arr.tobytes())
        manifest["leaves"][name] = {
            "file": fn,
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _cleanup(ckpt_dir, keep)
    return final


def save_async(ckpt_dir: str, step: int, state: dict, keep: int = 3) -> threading.Thread:
    """Fetch to host synchronously (cheap), write in a background thread."""
    host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_state, keep), daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int | None = None, target: Any = None,
            shardings: Any = None) -> tuple[int, Any]:
    """Load a checkpoint. ``target`` (a pytree with the desired structure)
    rebuilds nesting; ``shardings`` (same structure) re-places leaves — pass
    shardings from a *new* mesh to elastically reshard on restore."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    import ml_dtypes  # ships with jax; provides bfloat16 numpy dtype

    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {}
    for name, meta in manifest["leaves"].items():
        dtype = np.dtype(
            getattr(ml_dtypes, meta["dtype"], None) or np.dtype(meta["dtype"])
        )
        with open(os.path.join(path, meta["file"]), "rb") as f:
            arr = np.frombuffer(f.read(), dtype=dtype).reshape(meta["shape"])
        flat[name] = arr

    if target is None:
        return manifest["step"], flat

    leaves_t, treedef = jax.tree.flatten(target)
    flat_t = _flatten(target)
    assert set(flat_t) == set(flat), (
        f"checkpoint/target mismatch: {set(flat_t) ^ set(flat)}"
    )
    sh_flat = _flatten(shardings) if shardings is not None else {}
    rebuilt = []
    for name in _flatten_names(target):
        arr = flat[name]
        if name in sh_flat and sh_flat[name] is not None:
            arr = jax.device_put(arr, sh_flat[name])
        rebuilt.append(arr)
    return manifest["step"], jax.tree.unflatten(treedef, rebuilt)


def _flatten_names(tree: Any, prefix: str = "") -> list[str]:
    # must mirror jax.tree.flatten's traversal: dict keys in sorted order
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            v = tree[k]
            out.extend(_flatten_names(v, f"{prefix}{_SEP}{k}" if prefix else str(k)))
        return out
    if isinstance(tree, (tuple, list)):
        out = []
        for i, v in enumerate(tree):
            out.extend(_flatten_names(v, f"{prefix}{_SEP}{i}" if prefix else str(i)))
        return out
    return [prefix]


def _cleanup(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(ckpt_dir) if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
