"""Obs-driven replica autoscaling: the closed loop from signals to fleet.

An :class:`AutoScaler` is a pull-based control loop over the signals the
observability layer already records — router queue depth, shed/admission
counters, worst-class p95 — plus (optionally) the firing set of an
:class:`~repro.obs.alerts.AlertEngine`, actuating through
:meth:`repro.fleet.Fleet.add_replica` /
:meth:`repro.fleet.Fleet.remove_replica` and
:meth:`repro.fleet.FleetRouter.attach_lane` /
:meth:`~repro.fleet.FleetRouter.detach_lane`::

    streams (slo/admission) ──▶ AlertEngine ──▶ firing("admission_overload")
                 │                                   │
                 ▼                                   ▼
    router.slo_report() ────────────────▶ AutoScaler.tick()
                                            │ scale_up:   Fleet.add_replica
                                            │             (full resync join)
                                            │             router.attach_lane
                                            │ scale_down: router.detach_lane
                                            │             Fleet.remove_replica
                                            ▼
                                          `autoscale` stream (every decision,
                                          with the alert that triggered it)

Like the :class:`~repro.obs.SLOSampler`, nothing here owns a thread: the
serve loop calls :meth:`AutoScaler.tick` at its sampling cadence, so with
``--autoscale`` off the object is never built and the request path is
untouched.

Scale-down only retires replicas this scaler added (newest first), never a
launch-time replica — the operator's configured floor is the floor.
"""
from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass(frozen=True)
class AutoScaleConfig:
    """Control-loop thresholds and bounds.

    Scale **up** when any pressure signal trips: router depth at/above
    ``scale_up_depth``, new sheds since the last tick, an active admission
    shed floor, worst-class p95 above ``scale_up_p95_ms`` (when set), or an
    ``overload_alerts`` rule firing. Scale **down** only after
    ``quiesce_ticks`` consecutive calm ticks (depth at/below
    ``scale_down_depth``, no pressure). ``cooldown_s`` spaces *any* two
    actuations — a scale-up is never followed by a flapping scale-down one
    tick later.
    """

    min_replicas: int = 1
    max_replicas: int = 4
    scale_up_depth: int = 64
    scale_up_p95_ms: float | None = None
    scale_down_depth: int = 4
    quiesce_ticks: int = 3
    cooldown_s: float = 5.0
    # Only *instantaneous* overload rules belong here: the router's p95 is
    # cumulative over the completion history, so a latency alert keeps
    # firing long after the overload drained and would pin the pool at max
    # (p95-based scaling is opt-in via scale_up_p95_ms, which reads the
    # live report, not an alert).
    overload_alerts: tuple[str, ...] = (
        "admission_overload", "queue_depth_high",
    )

    def __post_init__(self):
        if self.min_replicas < 1:
            raise ValueError("min_replicas must be >= 1")
        if self.max_replicas < self.min_replicas:
            raise ValueError("max_replicas must be >= min_replicas")
        if self.scale_up_depth < 1 or self.scale_down_depth < 0:
            raise ValueError("bad depth thresholds")
        if self.quiesce_ticks < 1:
            raise ValueError("quiesce_ticks must be >= 1")


class AutoScaler:
    """Scale one workload's replica pool from its observed load."""

    def __init__(self, fleet, router, workload: str,
                 config: AutoScaleConfig | None = None, *,
                 recorder=None, engine=None, clock=time.monotonic):
        self.fleet = fleet
        self.router = router
        self.workload = workload
        self.config = config or AutoScaleConfig()
        self.recorder = recorder  # decisions land on the `autoscale` stream
        self.engine = engine  # optional AlertEngine: alert-to-action link
        self.clock = clock
        self.events = {"scale_up": 0, "scale_down": 0, "blocked": 0}
        self.ticks = 0
        self._added: list[str] = []  # replica names we spawned (LIFO retire)
        self._last_action_s: float | None = None
        self._last_shed = 0
        self._calm = 0

    @property
    def outstanding(self) -> int:
        """Replicas this scaler has added and not yet retired."""
        return len(self._added)

    def observe(self) -> dict:
        """Read the signals without acting. Refreshes the shed-delta
        baseline — call before a quiesce phase so sheds from an already-
        handled burst don't read as fresh pressure on the next tick."""
        return self._signals()

    # -- signal read-out -----------------------------------------------------

    def _signals(self) -> dict:
        report = self.router.slo_report()
        adm = report.get("admission") or {}
        p95s = [
            entry.get("p95_ms")
            for entry in report.get("classes", {}).values()
            if entry.get("p95_ms") is not None
        ]
        shed = report.get("shed", 0)
        shed_delta = max(shed - self._last_shed, 0)
        self._last_shed = shed
        firing = set(self.engine.firing()) if self.engine is not None else set()
        return {
            "depth": adm.get("depth", 0),
            "shed_floor": adm.get("shed_floor"),
            "shed_delta": shed_delta,
            "p95_ms": max(p95s) if p95s else None,
            "firing": firing,
        }

    def _pressure(self, sig: dict) -> str | None:
        """The first pressure reason tripping, or None when calm."""
        cfg = self.config
        overload = sorted(sig["firing"] & set(cfg.overload_alerts))
        if overload:
            return f"alert:{overload[0]}"
        if sig["shed_floor"] is not None:
            return f"shed_floor={sig['shed_floor']}"
        if sig["shed_delta"]:
            return f"shed_delta={sig['shed_delta']}"
        if sig["depth"] >= cfg.scale_up_depth:
            return f"depth={sig['depth']}"
        if cfg.scale_up_p95_ms is not None and sig["p95_ms"] is not None \
                and sig["p95_ms"] > cfg.scale_up_p95_ms:
            return f"p95_ms={sig['p95_ms']:.1f}"
        return None

    def _cooled(self, now: float) -> bool:
        return (self._last_action_s is None
                or now - self._last_action_s >= self.config.cooldown_s)

    # -- the control loop ----------------------------------------------------

    def tick(self) -> dict:
        """One control-loop pass: read signals, maybe actuate. Returns the
        decision record (``action`` of ``scale_up`` / ``scale_down`` /
        ``hold``). Actuations and *blocked* intents (pressure with the pool
        at max, or inside cooldown) are recorded on the ``autoscale``
        stream; calm holds are not — the stream is a decision history, not
        a heartbeat."""
        self.ticks += 1
        cfg = self.config
        sig = self._signals()
        now = self.clock()
        n = self.fleet.replica_count(self.workload)
        reason = self._pressure(sig)
        decision = {
            "action": "hold",
            "reason": reason or "calm",
            "replicas_before": n,
            "replicas_after": n,
            "depth": sig["depth"],
            "shed_delta": sig["shed_delta"],
            "p95_ms": sig["p95_ms"],
            "alerts_firing": ",".join(sorted(sig["firing"])),
        }
        record = False
        if reason is not None:
            self._calm = 0
            if n >= cfg.max_replicas:
                decision["reason"] = f"{reason} (blocked: at max_replicas)"
                self.events["blocked"] += 1
                record = True
            elif not self._cooled(now):
                decision["reason"] = f"{reason} (blocked: cooldown)"
                self.events["blocked"] += 1
                record = True
            else:
                shard, replica = self.fleet.add_replica(self.workload)
                self.router.attach_lane(shard, replica)
                self._added.append(replica.name)
                self._last_action_s = now
                self.events["scale_up"] += 1
                decision.update(action="scale_up", replica=replica.name,
                                replicas_after=n + 1)
                record = True
        else:
            calm = sig["depth"] <= cfg.scale_down_depth
            self._calm = self._calm + 1 if calm else 0
            if (self._calm >= cfg.quiesce_ticks and self._added
                    and n > cfg.min_replicas and self._cooled(now)):
                name = self._added.pop()
                self.router.detach_lane(self.workload, name)
                self.fleet.remove_replica(self.workload, replica_name=name)
                self._last_action_s = now
                self._calm = 0
                self.events["scale_down"] += 1
                decision.update(action="scale_down", replica=name,
                                replicas_after=n - 1,
                                reason=f"quiesce ({cfg.quiesce_ticks} calm "
                                       f"ticks)")
                record = True
        if record and self.recorder is not None:
            self.recorder.record("autoscale", decision)
        return decision
