"""Request routing with per-class priority and overload admission control.

The fleet-side counterpart of :class:`repro.serving.queue.RequestQueue`:
requests enter through :meth:`FleetRouter.submit`, are admitted or shed by
the overload policy, land on the least-loaded replica lane of their
workload's shards, and are served in priority order as same-class batches
against one pinned replica snapshot (the queue's result-transparency
carries over — the evaluator is identical).

Admission control (:class:`AdmissionConfig`) sheds the *lowest* priority
class first: when total queue depth crosses ``max_depth`` — or the
deadline-miss rate predicted from the trailing completions crosses
``max_miss_rate`` — the shed floor rises one priority level per multiple
of ``max_depth``, so progressively more classes are refused while the top
class is always admitted. Shed requests fail fast (``error="shed: ..."``)
instead of queuing toward certain deadline misses, and
:meth:`FleetRouter.slo_report` extends the queue's per-class SLO tables
with ``admitted``/``shed`` counters plus the live admission state.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import defaultdict, deque

import numpy as np

from ..core.stats import build_slo_report
from ..partition.combine import combine_snapshots
from ..serving.queue import Request
from ..serving.resident import Snapshot, SnapshotEvaluator
from .replica import ReplicaDeadError
from .topology import Fleet, FleetShard


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Overload thresholds.

    ``max_depth``: pending requests across the router before the shed floor
    rises (then one more level per additional multiple);
    ``max_miss_rate``: predicted deadline-miss rate (trailing
    ``miss_window`` completions) that raises the floor one level;
    ``min_observations``: completions required before the miss predictor is
    trusted at all.
    """

    max_depth: int = 256
    max_miss_rate: float = 0.5
    miss_window: int = 64
    min_observations: int = 16

    def __post_init__(self):
        if self.max_depth < 1:
            raise ValueError("max_depth must be >= 1")
        if not 0.0 < self.max_miss_rate <= 1.0:
            raise ValueError("max_miss_rate must be in (0, 1]")


class _Lane:
    """One replica's pending queue."""

    __slots__ = ("shard", "replica", "pending", "served", "dead",
                 "retired", "inflight", "win_version", "win_snap")

    def __init__(self, shard: FleetShard, replica):
        self.shard = shard
        self.replica = replica
        self.pending: list[Request] = []
        self.served = 0
        # Set when the replica's transport fails (ReplicaDeadError): the
        # lane stops taking submissions and its backlog is rerouted to the
        # surviving lanes. revive() re-admits it once the replica answers
        # pings again (after ReplicaProcess.restart()).
        self.dead = False
        # Set by detach_lane (autoscaler scale-down): a clean retirement —
        # the lane takes no new batches, its worker thread exits, and
        # detach waits for `inflight` (batches mid-serve) to drain before
        # the replica may be closed.
        self.retired = False
        self.inflight = 0
        # Combine-at-query window cache (subposterior workloads only):
        # the last window this router pulled from the replica and its
        # version, so an unchanged window never re-crosses the transport.
        self.win_version = -1
        self.win_snap: Snapshot | None = None


class FleetRouter:
    """Route requests across a fleet's replicas; shed under overload."""

    def __init__(
        self,
        fleet: Fleet,
        *,
        priorities: dict[str, int] | None = None,
        admission: AdmissionConfig | None = None,
        max_batch: int | None = None,
        default_deadline_s: float | None = None,
        lanes_per_shard: int | None = None,
        tracer=None,
    ):
        self.fleet = fleet
        self.priorities = dict(priorities or {})
        self.admission = admission or AdmissionConfig()
        # Optional repro.obs.trace.Tracer — same span taxonomy as the
        # RequestQueue, plus replica_serve spans shipped back from replica
        # processes and combine spans on the subposterior path.
        self.tracer = tracer
        cfg = fleet.config.serving
        self.max_batch = int(max_batch or cfg.max_batch)
        self.default_deadline_s = (
            cfg.default_deadline_s if default_deadline_s is None
            else float(default_deadline_s)
        )
        # lanes_per_shard restricts serving to each shard's first N replicas
        # (None = all) — how the fleet bench sweeps replica counts over one
        # warmed fleet instead of rebuilding it per point.
        self._lanes: dict[str, list[_Lane]] = {
            workload: [
                _Lane(shard, replica)
                for shard in fleet.shards(workload)
                for replica in shard.replicas[:lanes_per_shard]
            ]
            for workload in fleet.workloads()
        }
        # Subposterior workloads serve through the combine-at-query path:
        # per-partition lane groups, a per-workload combined-snapshot cache
        # keyed by the partition version tuple, and one evaluator per
        # workload for the combined windows. P=1 workloads never touch any
        # of this — their serve path is byte-identical to before.
        self._partitioned: dict[str, int] = {
            w: fleet.num_partitions(w)
            for w in fleet.workloads()
            if fleet.num_partitions(w) > 1
        }
        self._partition_lanes: dict[str, dict[int, list[_Lane]]] = {}
        for workload, num_p in self._partitioned.items():
            groups: dict[int, list[_Lane]] = {p: [] for p in range(num_p)}
            for lane in self._lanes[workload]:
                groups[lane.shard.partition].append(lane)
            self._partition_lanes[workload] = groups
        self._combine_lock = threading.Lock()
        self._combined_cache: dict[str, tuple[tuple, Snapshot]] = {}
        self._combine_evaluators: dict[str, SnapshotEvaluator] = {
            w: SnapshotEvaluator(cfg.micro_batch) for w in self._partitioned
        }
        self._lock = threading.Lock()
        self._arrived = threading.Condition(self._lock)
        self._completed: list[Request] = []
        self._miss_trail: deque[bool] = deque(maxlen=self.admission.miss_window)
        self._counters: dict[tuple[str, str], dict] = defaultdict(
            lambda: {"admitted": 0, "shed": 0}
        )
        self._lane_deaths = 0
        self._rerouted = 0
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        self._max_wait_s = 0.002

    # -- admission ---------------------------------------------------------

    def _priority(self, query_class: str) -> int:
        return self.priorities.get(query_class, 0)

    def _depth_locked(self) -> int:
        return sum(len(l.pending) for lanes in self._lanes.values() for l in lanes)

    def _miss_rate_locked(self) -> float:
        """Deadline-miss rate over the trailing completions (0 until
        ``min_observations`` have been seen). Caller holds ``_lock``."""
        if len(self._miss_trail) < self.admission.min_observations:
            return 0.0
        return float(np.mean(self._miss_trail))

    def predicted_miss_rate(self) -> float:
        with self._lock:
            return self._miss_rate_locked()

    def _shed_floor_locked(self) -> int | None:
        """The priority strictly below which submissions are shed right
        now, or None when everything is admitted."""
        levels = sorted({self._priority(c) for c in self._known_classes()})
        if len(levels) < 2:
            return None  # one class: nothing lower-priority to shed first
        adm = self.admission
        depth = self._depth_locked()
        miss = self._miss_rate_locked()
        cut = 0
        if miss > adm.max_miss_rate:
            cut = 1
        if depth >= adm.max_depth:
            cut = max(cut, int(depth // adm.max_depth))
        cut = min(cut, len(levels) - 1)  # the top class is always admitted
        return None if cut == 0 else levels[cut]

    def _known_classes(self) -> set[str]:
        classes = set(self.priorities)
        for workload in self.fleet.workloads():
            classes.update(self.fleet.workload(workload).query_specs)
        return classes

    # -- intake ------------------------------------------------------------

    def submit(
        self, workload: str, query_class: str, xs, deadline_s: float | None = None
    ) -> Request:
        """Admit (routing to the least-loaded replica lane) or shed."""
        req = Request(
            workload=workload,
            query_class=query_class,
            xs=np.asarray(xs),
            deadline_s=self.default_deadline_s if deadline_s is None else deadline_s,
            submitted_at=time.monotonic(),
        )
        if self.tracer is not None:
            root = self.tracer.new_trace(
                f"request:{workload}.{query_class}", "request",
                workload=workload, query_class=query_class, request_id=req.id,
            )
            req.trace_id = root["trace_id"]
            req.trace = {"root": root}
        with self._arrived:
            counters = self._counters[(workload, query_class)]
            floor = self._shed_floor_locked()
            if floor is not None and self._priority(query_class) < floor:
                req.error = (
                    f"shed: admission floor at priority {floor} "
                    f"(depth={self._depth_locked()}, "
                    f"predicted_miss={np.mean(self._miss_trail) if self._miss_trail else 0.0:.2f})"
                )
                req.latency_s = 0.0
                req.deadline_met = False
                req.batch_size = 0
                counters["shed"] += 1
                self._completed.append(req)
                self._finish_req_trace(req, shed=True)
                req.done.set()
                return req
            counters["admitted"] += 1
            lanes = [l for l in self._lanes[workload] if not l.dead]
            if not lanes:
                req.error = (
                    f"ReplicaDeadError: no live replica lanes for "
                    f"workload {workload!r}"
                )
                req.latency_s = 0.0
                req.deadline_met = False
                req.batch_size = 0
                self._completed.append(req)
                self._finish_req_trace(req)
                req.done.set()
                return req
            if req.trace is not None:
                req.trace["queue"] = self.tracer.start(
                    req.trace_id, "queue_wait", "queue_wait",
                    parent_id=req.trace["root"]["span_id"],
                )
            lane = min(lanes, key=lambda l: (len(l.pending), l.served))
            lane.pending.append(req)
            self._arrived.notify_all()
        return req

    def _finish_req_trace(self, req: Request, **tags) -> None:
        """Close a completing request's open spans (root + any still-open
        queue_wait)."""
        if self.tracer is None or not req.trace:
            return
        if "queue" in req.trace:
            self.tracer.finish(req.trace.pop("queue"))
        root = req.trace.pop("root", None)
        if root is not None:
            self.tracer.finish(
                root,
                error=req.error,
                deadline_met=req.deadline_met,
                batch_size=req.batch_size,
                **tags,
            )

    @property
    def pending_count(self) -> int:
        with self._lock:
            return self._depth_locked()

    @property
    def completed(self) -> list[Request]:
        with self._lock:
            return list(self._completed)

    # -- serving -----------------------------------------------------------

    def _take_batch(self, lane: _Lane) -> list[Request]:
        """Pop up to ``max_batch`` same-class requests, highest priority
        class first (FIFO within the class). An idle lane steals from the
        deepest backlog of the same workload — replicas of one workload are
        interchangeable, and stealing keeps the tail from being set by the
        slowest replica's private queue."""
        with self._lock:
            if lane.dead or lane.retired:
                return []
            source = lane
            if not source.pending:
                peers = self._lanes[lane.shard.workload]
                source = max(peers, key=lambda l: len(l.pending))
                if not source.pending:
                    return []
            head = max(source.pending,
                       key=lambda r: (self._priority(r.query_class), -r.id))
            key = head.query_class
            batch, rest = [], []
            for req in source.pending:
                if req.query_class == key and len(batch) < self.max_batch:
                    batch.append(req)
                else:
                    rest.append(req)
            source.pending = rest
        if self.tracer is not None:
            for req in batch:
                if req.trace and "queue" in req.trace:
                    self.tracer.finish(req.trace.pop("queue"))
        return batch

    # -- subposterior combine-at-query --------------------------------------

    def _partition_window(self, workload: str, p: int) -> Snapshot:
        """The freshest available window for partition ``p``: first live
        lane that answers, via the version-gated ``window()`` fetch (an
        unchanged window reuses the lane's cached copy). Dead transports
        are marked dead and the next lane tried; a partition with no live
        lane raises — a combined posterior needs *every* partition."""
        for lane in self._partition_lanes[workload][p]:
            if lane.dead:
                continue
            try:
                version, snap = lane.replica.window(lane.win_version)
            except ReplicaDeadError:
                self._on_lane_death(lane, [])
                continue
            if snap is not None:
                lane.win_version, lane.win_snap = version, snap
            if lane.win_snap is not None:
                return lane.win_snap
        raise ReplicaDeadError(
            f"no live replica window for workload {workload!r} "
            f"partition {p}"
        )

    def _combined_snapshot(self, workload: str) -> Snapshot:
        """One full-posterior snapshot from the P per-partition windows,
        cached per partition-version tuple (caller holds ``_combine_lock``).
        ``steps_done`` of the result is the version sum — the strictly
        increasing generation key the shared evaluator caches on."""
        snaps = [
            self._partition_window(workload, p)
            for p in range(self._partitioned[workload])
        ]
        versions = tuple(s.steps_done for s in snaps)
        cached = self._combined_cache.get(workload)
        if cached is not None and cached[0] == versions:
            return cached[1]
        combined = combine_snapshots(snaps, self.fleet.config.combine)
        self._combined_cache[workload] = (versions, combined)
        return combined

    def _serve_combined(
        self, workload: str, qclass: str, xs, trace=None
    ) -> tuple[np.ndarray, float]:
        """Serve a batch from the combined subposterior window (the
        partitioned counterpart of ``lane.replica.serve``). ``trace =
        (trace_id, parent_span_id)`` wraps the window-gather + combine in a
        ``combine`` span with the evaluator's ``device_eval`` span nested
        under it."""
        spec = self.fleet.spec(workload, qclass)
        combine_span = sink = None
        if trace is not None and self.tracer is not None:
            combine_span = self.tracer.start(
                trace[0], f"combine:{workload}", "combine",
                parent_id=trace[1], partitions=self._partitioned[workload],
            )
            sink = []
        with self._combine_lock:
            snap = self._combined_snapshot(workload)
            values = self._combine_evaluators[workload].evaluate(
                spec, snap, xs, span_sink=sink
            )
        if combine_span is not None:
            self.tracer.finish(combine_span)
            if sink:
                self.tracer.adopt(sink, trace[0],
                                  parent_id=combine_span["span_id"])
        return values, snap.staleness_s

    # -- serving (continued) ------------------------------------------------

    def _serve_batch(self, lane: _Lane, batch: list[Request]) -> None:
        with self._lock:
            lane.inflight += 1
        try:
            self._serve_batch_inner(lane, batch)
        finally:
            with self._lock:
                lane.inflight -= 1

    def _serve_batch_inner(self, lane: _Lane, batch: list[Request]) -> None:
        workload, qclass = batch[0].workload, batch[0].query_class
        # Batch-level spans hang off the batch head's trace (same convention
        # as RequestQueue._serve_batch); the replica leg is traced by the
        # replica itself — in its own process for the proc transport — and
        # its spans ride back inside the query reply.
        head = batch[0].trace if self.tracer is not None else None
        trace = (head["root"]["trace_id"], head["root"]["span_id"]) \
            if head else None
        asm = None
        try:
            if trace is not None:
                asm = self.tracer.start(
                    trace[0], "batch_assembly", "assembly",
                    parent_id=trace[1], batch_size=len(batch),
                    lane=lane.replica.name,
                )
            sizes = [req.xs.shape[0] if req.xs.ndim else 1 for req in batch]
            xs = np.concatenate([np.atleast_1d(req.xs) for req in batch], axis=0)
            if asm is not None:
                self.tracer.finish(asm, rows=int(xs.shape[0]))
                asm = None
            if workload in self._partitioned:
                # Rerouting cannot help a combine that is missing a whole
                # partition, so a ReplicaDeadError here fails the batch
                # (the generic handler below) instead of cascading lane
                # deaths through _on_lane_death.
                values, staleness = self._serve_combined(
                    workload, qclass, xs, trace=trace
                )
            else:
                spec = self.fleet.spec(workload, qclass)
                if trace is None:
                    values, staleness = lane.replica.serve(spec, qclass, xs)
                else:
                    values, staleness, spans = lane.replica.serve(
                        spec, qclass, xs, trace=trace
                    )
                    for span in spans:
                        self.tracer.emit(span)
        except ReplicaDeadError:
            if asm is not None:
                self.tracer.finish(asm, error="ReplicaDeadError")
            if workload in self._partitioned:
                now = time.monotonic()
                with self._lock:
                    for req in batch:
                        req.error = (
                            "ReplicaDeadError: a subposterior partition has "
                            f"no live replica window for {workload!r}"
                        )
                        req.latency_s = now - req.submitted_at
                        req.deadline_met = False
                        req.batch_size = len(batch)
                        self._miss_trail.append(True)
                        self._finish_req_trace(req)
                        req.done.set()
                    self._completed.extend(batch)
                return
            # The replica (not the request) failed: the batch is still
            # servable, so reroute it — plus the lane's whole backlog —
            # to the surviving lanes instead of failing it. Root spans stay
            # open; the serving lane closes them when the request finishes.
            self._on_lane_death(lane, batch)
            return
        except Exception as e:  # noqa: BLE001 — fail the requests, not the server
            now = time.monotonic()
            if asm is not None:
                self.tracer.finish(asm, error=type(e).__name__)
            with self._lock:
                for req in batch:
                    req.error = f"{type(e).__name__}: {e}"
                    req.latency_s = now - req.submitted_at
                    req.deadline_met = False
                    req.batch_size = len(batch)
                    self._miss_trail.append(True)
                    self._finish_req_trace(req)
                    req.done.set()
                self._completed.extend(batch)
            return
        now = time.monotonic()
        offset = 0
        with self._lock:
            for req, size in zip(batch, sizes):
                req.values = values[offset:offset + size]
                offset += size
                req.latency_s = now - req.submitted_at
                req.deadline_met = req.latency_s <= req.deadline_s
                req.staleness_s = staleness
                req.batch_size = len(batch)
                self._miss_trail.append(not req.deadline_met)
                self._finish_req_trace(req)
                req.done.set()
            lane.served += len(batch)
            self._completed.extend(batch)

    def _on_lane_death(self, lane: _Lane, batch: list[Request]) -> None:
        """Mark a lane dead and reroute its in-flight batch plus backlog.

        Requests keep their original ``submitted_at`` — the extra latency a
        failover costs is real and must show in the SLO tables. Only when no
        live lane remains do the stranded requests fail."""
        with self._arrived:
            if not lane.dead:
                lane.dead = True
                self._lane_deaths += 1
            stranded = batch + lane.pending
            lane.pending = []
            live = [l for l in self._lanes[lane.shard.workload] if not l.dead]
            if not live:
                now = time.monotonic()
                for req in stranded:
                    req.error = (
                        f"ReplicaDeadError: no live replica lanes for "
                        f"workload {lane.shard.workload!r}"
                    )
                    req.latency_s = now - req.submitted_at
                    req.deadline_met = False
                    req.batch_size = 0
                    self._miss_trail.append(True)
                    self._finish_req_trace(req)
                    req.done.set()
                self._completed.extend(stranded)
                return
            for req in stranded:
                target = min(live, key=lambda l: (len(l.pending), l.served))
                target.pending.append(req)
                self._rerouted += 1
            self._arrived.notify_all()

    # -- runtime lane scaling ----------------------------------------------

    def attach_lane(self, shard: FleetShard, replica) -> None:
        """Add a serving lane for a runtime-spawned replica (the scale-up
        actuation; pair of :meth:`repro.fleet.Fleet.add_replica`).

        The lane joins the workload's least-loaded selection immediately;
        when background workers are running it gets its own serving thread,
        so attach works mid-load without a router restart."""
        lane = _Lane(shard, replica)
        with self._arrived:
            self._lanes[shard.workload].append(lane)
            groups = self._partition_lanes.get(shard.workload)
            if groups is not None:
                groups[shard.partition].append(lane)
            spawn = bool(self._threads)
            self._arrived.notify_all()
        if spawn:
            self._spawn_worker(lane)

    def detach_lane(self, workload: str, replica_name: str,
                    timeout_s: float = 30.0) -> bool:
        """Cleanly retire one lane without dropping requests (the
        scale-down actuation; call **before**
        :meth:`repro.fleet.Fleet.remove_replica` closes the replica).

        The lane is removed from the routing set, its backlog is rerouted
        to the surviving lanes (or failed, only if none remain — the
        min-replica bound upstream prevents that), its worker thread exits,
        and this method blocks until any batch the lane is serving right
        now has completed, so the caller may close the replica the moment
        it returns. Returns False when no live lane matches."""
        with self._arrived:
            lanes = self._lanes[workload]
            lane = next(
                (l for l in lanes if l.replica.name == replica_name), None
            )
            if lane is None:
                return False
            lane.retired = True
            stranded = lane.pending
            lane.pending = []
            lanes.remove(lane)
            groups = self._partition_lanes.get(workload)
            if groups is not None and lane in groups[lane.shard.partition]:
                groups[lane.shard.partition].remove(lane)
            live = [l for l in lanes if not l.dead]
            if stranded and live:
                for req in stranded:
                    target = min(live, key=lambda l: (len(l.pending), l.served))
                    target.pending.append(req)
                    self._rerouted += 1
            elif stranded:
                now = time.monotonic()
                for req in stranded:
                    req.error = (
                        f"ReplicaDeadError: no live replica lanes for "
                        f"workload {workload!r}"
                    )
                    req.latency_s = now - req.submitted_at
                    req.deadline_met = False
                    req.batch_size = 0
                    self._miss_trail.append(True)
                    self._finish_req_trace(req)
                    req.done.set()
                self._completed.extend(stranded)
            self._arrived.notify_all()
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            with self._lock:
                if not lane.inflight:
                    return True
            time.sleep(0.005)
        return True  # timed out waiting; caller's close() will surface it

    def revive(self) -> int:
        """Re-admit dead lanes whose replica answers pings again (after a
        :meth:`ReplicaProcess.restart` + resync); returns how many."""
        revived = 0
        for lanes in self._lanes.values():
            for lane in lanes:
                if lane.dead and lane.replica.ping():
                    with self._lock:
                        lane.dead = False
                    revived += 1
        return revived

    @property
    def dead_lanes(self) -> int:
        with self._lock:
            return sum(
                l.dead for lanes in self._lanes.values() for l in lanes
            )

    def drain(self) -> list[Request]:
        """Serve everything pending on the calling thread (deterministic;
        what tests and the smoke path use), round-robin over lanes."""
        served: list[Request] = []
        while True:
            any_served = False
            for lanes in self._lanes.values():
                for lane in lanes:
                    batch = self._take_batch(lane)
                    if batch:
                        self._serve_batch(lane, batch)
                        # A batch that hit a dead lane was rerouted, not
                        # completed — count each request where it finishes.
                        served.extend(r for r in batch if r.done.is_set())
                        any_served = True
            if not any_served:
                return served

    # -- background workers ------------------------------------------------

    def _lane_loop(self, lane: _Lane) -> None:
        while not self._stop.is_set() and not lane.retired:
            with self._arrived:
                if not lane.pending:
                    self._arrived.wait(timeout=0.02)
            if self._max_wait_s:
                time.sleep(self._max_wait_s)  # let a batch accumulate first
            # One take AFTER the linger: _take_batch already caps at
            # max_batch and keeps the batch single-class (a second take
            # could return a different class, and truncating a merged
            # batch would orphan popped requests).
            batch = self._take_batch(lane)
            if batch:
                self._serve_batch(lane, batch)

    def _spawn_worker(self, lane: _Lane) -> None:
        t = threading.Thread(
            target=self._lane_loop, args=(lane,),
            name=f"route-{lane.replica.name}", daemon=True,
        )
        t.start()
        self._threads.append(t)

    def start_workers(self, max_wait_s: float = 0.002) -> None:
        """One serving thread per replica lane — with process-transport
        replicas each lane's RPC blocks GIL-free, so lanes genuinely serve
        in parallel. Lanes attached later (:meth:`attach_lane`) get their
        own worker on attach."""
        if self._threads:
            return
        self._stop.clear()
        self._max_wait_s = max_wait_s
        for lanes in self._lanes.values():
            for lane in lanes:
                self._spawn_worker(lane)

    def stop_workers(self, timeout_s: float = 30.0) -> None:
        self._stop.set()
        with self._arrived:
            self._arrived.notify_all()
        for t in self._threads:
            t.join(timeout=timeout_s)
        self._threads = []

    # -- SLO accounting ----------------------------------------------------

    def slo_report(self) -> dict:
        """The queue's per-class SLO tables (same unified
        :func:`repro.core.stats.build_slo_report` schema) extended with
        admission-control counters per class plus the router-wide admission
        and lane-recovery state."""
        with self._lock:
            done = [r for r in self._completed if r.latency_s is not None]
            counters = {k: dict(v) for k, v in self._counters.items()}
            depth = self._depth_locked()
            floor = self._shed_floor_locked()
            miss = self._miss_rate_locked()
            recovery = {
                "lane_deaths": self._lane_deaths,
                "rerouted": self._rerouted,
                "dead_lanes": sum(
                    l.dead for lanes in self._lanes.values() for l in lanes
                ),
            }
        priorities = {qc: self._priority(qc) for qc in self._known_classes()}
        return build_slo_report(
            done,
            priorities=priorities,
            class_counters=counters,
            admission={
                "depth": depth,
                "predicted_miss_rate": miss,
                "shed_floor": floor,
            },
            recovery=recovery,
        ).to_dict()
