"""Read replicas: local posterior windows answering queries.

A :class:`ReplicaEnsemble` is the read-side half of a fleet shard: it holds
a delta-streamed copy of its writer's rolling window and serves posterior
functionals from that copy through the same
:class:`repro.serving.resident.SnapshotEvaluator` the writer uses — no
forked query path, so a replica's answers are bit-for-bit what the writer
would serve from the same version (regression-tested).

:class:`ReplicaProcess` hosts one ReplicaEnsemble in its own OS process —
the fleet's "process group" transport. Deltas and query batches travel
over a pipe (pickled; :func:`repro.fleet.delta.wire_bytes` is literally
what crosses), and because each replica process owns a private Python
interpreter and XLA client, replicas serve genuinely in parallel on
multi-core hosts — the replica-scaling axis ``benchmarks/fleet_bench.py``
measures. The worker rebuilds its workload's query specs from the serving
registry by name (specs hold closures, which don't pickle across a spawn).
"""
from __future__ import annotations

import multiprocessing as mp
import pickle
import threading
import time
from typing import Any

import jax
import numpy as np

from ..obs.trace import new_span_id, span_close, span_open
from ..serving.resident import QuerySpec, Snapshot, SnapshotEvaluator
from .delta import SnapshotDelta, apply_delta, wire_bytes

Params = Any


class ReplicaDeadError(ConnectionError):
    """The replica itself (not the request) failed: its process died, its
    pipe broke, or it was killed. Callers treat this differently from a
    request-level error — the fleet sync loop skips the replica and keeps
    broadcasting, and the router marks the lane dead and reroutes the batch
    to the surviving lanes instead of failing it."""


class ReplicaEnsemble:
    """An in-process read replica: local window copy + shared evaluator.

    Thread-safe like the resident: ``apply_delta`` replaces (never mutates)
    the window under a lock; snapshots are immutable once taken.
    """

    def __init__(self, name: str, *, micro_batch: int = 64):
        self.name = name
        self.version = 0  # writer steps_done our window mirrors
        self._draws = None
        self._summary: dict = {}
        self._base_staleness = 0.0  # writer-side staleness at last sync
        self._last_update: float | None = None
        self._evaluator = SnapshotEvaluator(micro_batch)
        self._lock = threading.RLock()
        self._dead = False
        self.deltas_applied = 0
        self.full_syncs = 0
        self.bytes_received = 0

    def apply_delta(self, delta: SnapshotDelta, *, nbytes: int | None = None) -> int:
        """Fold a writer delta into the local window; returns the version.

        An incremental delta whose ``base_version`` doesn't match raises —
        the caller (the fleet sync loop) then re-emits a full resync.
        """
        with self._lock:
            if self._dead:
                raise ReplicaDeadError(f"replica {self.name!r} is down (killed)")
            if not delta.full and delta.draws is not None \
                    and delta.base_version != self.version:
                raise ValueError(
                    f"replica {self.name!r} at version {self.version} cannot "
                    f"apply incremental delta from base {delta.base_version}; "
                    "full resync required"
                )
            self._draws = apply_delta(self._draws, delta)
            self.version = delta.version
            self._summary = delta.summary
            self._base_staleness = delta.staleness_s
            self._last_update = time.monotonic()
            self.deltas_applied += 1
            self.full_syncs += int(delta.full)
            self.bytes_received += int(
                nbytes if nbytes is not None else wire_bytes(delta)
            )
            if delta.draws is not None:
                # The window changed under the same (steps_done, num_draws)
                # key only on resync-after-restore; invalidating is cheap
                # and always safe.
                self._evaluator.invalidate()
            return self.version

    def reset(self) -> None:
        """Forget the local copy (forces the next sync to be full)."""
        with self._lock:
            self._draws = None
            self.version = 0
            self._summary = {}
            self._base_staleness = 0.0
            self._last_update = None
            self._evaluator.invalidate()

    def snapshot(self) -> Snapshot:
        """The replica's local view. Staleness compounds the writer-side
        staleness at emission with the time since the delta arrived — a
        replica never under-reports how old its draws are."""
        with self._lock:
            now = time.monotonic()
            staleness = (
                float("inf") if self._last_update is None
                else self._base_staleness + (now - self._last_update)
            )
            num = 0
            if self._draws is not None:
                lead = jax.tree.leaves(self._draws)[0].shape
                num = int(lead[0] * lead[1])
            return Snapshot(
                draws=self._draws,
                num_draws=num,
                steps_done=self.version,
                staleness_s=staleness,
                summary=self._summary,
                created_at=now,
            )

    def query(
        self,
        spec: QuerySpec,
        xs,
        *,
        snapshot: Snapshot | None = None,
        span_sink: list | None = None,
    ) -> tuple[np.ndarray, Snapshot]:
        if self._dead:
            raise ReplicaDeadError(f"replica {self.name!r} is down (killed)")
        snap = snapshot if snapshot is not None else self.snapshot()
        if snap.draws is None:
            raise RuntimeError(
                f"replica {self.name!r} has no window yet; sync a delta first"
            )
        return self._evaluator.evaluate(spec, snap, xs, span_sink=span_sink), snap

    def serve(self, spec: QuerySpec, query_class: str, xs, trace=None):
        """The router-facing entry: returns ``(values, staleness_s)``, or —
        when the router passes ``trace=(trace_id, parent_span_id)`` —
        ``(values, staleness_s, spans)`` with the replica's own
        ``replica_serve`` span and its ``device_eval`` child, already keyed
        to the caller's trace. ``query_class`` is unused in-process (the
        spec is passed directly); the process transport resolves it
        registry-side instead."""
        del query_class
        if trace is None:
            values, snap = self.query(spec, xs)
            return values, snap.staleness_s
        values, snap, spans = _traced_query(self, spec, xs, trace)
        return values, snap.staleness_s, spans

    def window(self, known_version: int = -1) -> tuple[int, Snapshot | None]:
        """The replica's current window for combine-at-query: returns
        ``(version, snapshot)``, or ``(version, None)`` when the caller
        already holds ``known_version`` — the router's per-lane window
        cache then skips re-fetching an unchanged window (which, for the
        process transport, is a full-window pickle)."""
        with self._lock:
            if self._dead:
                raise ReplicaDeadError(f"replica {self.name!r} is down (killed)")
            if self.version == known_version and self._draws is not None:
                return self.version, None
            return self.version, self.snapshot()

    def stats(self) -> dict:
        with self._lock:
            return {
                "name": self.name,
                "version": self.version,
                "alive": not self._dead,
                "deltas_applied": self.deltas_applied,
                "full_syncs": self.full_syncs,
                "bytes_received": self.bytes_received,
            }

    # -- chaos / fault-injection surface (parity with ReplicaProcess) ------

    @property
    def alive(self) -> bool:
        return not self._dead

    def ping(self) -> bool:
        return not self._dead

    def kill(self) -> None:
        """Simulated crash for the in-process transport: every subsequent
        ``apply_delta``/``query`` raises :class:`ReplicaDeadError` until
        :meth:`restart` — what lets the chaos tests exercise the router's
        failover deterministically without spawning processes."""
        with self._lock:
            self._dead = True

    def restart(self) -> None:
        """Come back empty (a restarted replica has no window; the next
        sync is a full resync)."""
        with self._lock:
            self._dead = False
        self.reset()

    def close(self) -> None:  # interface parity with ReplicaProcess
        pass


def _traced_query(replica: ReplicaEnsemble, spec: QuerySpec, xs, trace):
    """Run a replica query under a ``replica_serve`` span with its
    ``device_eval`` child, both keyed to ``trace = (trace_id,
    parent_span_id)``. Returns ``(values, snap, spans)`` — closed, fully
    linked span dicts ready to :meth:`Tracer.emit` (for the process
    transport they pickle back over the pipe first)."""
    trace_id, parent_id = trace
    serve_span = span_open(trace_id, f"replica_serve:{replica.name}",
                           "replica_serve", parent_id=parent_id,
                           replica=replica.name)
    sink: list = []
    values, snap = replica.query(spec, xs, span_sink=sink)
    span_close(serve_span, version=replica.version)
    spans = [serve_span]
    for raw in sink:
        raw = dict(raw)
        raw["trace_id"] = trace_id
        if raw.get("span_id") is None:
            raw["span_id"] = new_span_id()
        raw["parent_id"] = serve_span["span_id"]
        spans.append(raw)
    return values, snap, spans


# ---------------------------------------------------------------------------
# Process-group transport
# ---------------------------------------------------------------------------


def _replica_worker(conn, name: str, workload_name: str, build_kw: dict,
                    micro_batch: int, threads: int | None) -> None:
    """Replica process main loop: build the workload's query specs from the
    registry, then answer pickled (cmd, ...) frames until ``stop``."""
    import os

    if threads:
        # Cap this replica's XLA intra-op pool BEFORE the backend
        # initializes (module import is fine; the first op is not). One
        # compute thread per replica is what makes N replicas scale on an
        # M-core host instead of thrashing one shared pool.
        flags = os.environ.get("XLA_FLAGS", "")
        if "intra_op_parallelism_threads" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_cpu_multi_thread_eigen=false "
                f"intra_op_parallelism_threads={threads}"
            ).strip()
    from ..serving.workloads import build_serving_workload

    try:
        workload = build_serving_workload(workload_name, **build_kw)
        replica = ReplicaEnsemble(name, micro_batch=micro_batch)
        conn.send_bytes(pickle.dumps(("ready", name)))
    except Exception as e:  # noqa: BLE001 — report the failure, then exit
        conn.send_bytes(pickle.dumps(("err", f"{type(e).__name__}: {e}")))
        return
    while True:
        try:
            msg = pickle.loads(conn.recv_bytes())
        except EOFError:
            return
        cmd = msg[0]
        if cmd == "stop":
            conn.send_bytes(pickle.dumps(("ok",)))
            return
        try:
            if cmd == "delta":
                version = replica.apply_delta(msg[1], nbytes=msg[2])
                out = ("ok", version)
            elif cmd == "query":
                # 3-tuple = untraced (the wire format predating tracing);
                # a 4th element carries (trace_id, parent_span_id) and asks
                # for this replica's spans back in a 5-tuple reply.
                _, query_class, xs, *rest = msg
                trace = rest[0] if rest else None
                spec = workload.query_specs[query_class]
                if trace is None:
                    values, snap = replica.query(spec, xs)
                    out = ("ok", values, snap.staleness_s, replica.version)
                else:
                    values, snap, spans = _traced_query(replica, spec, xs, trace)
                    out = ("ok", values, snap.staleness_s, replica.version, spans)
            elif cmd == "window":
                version, snap = replica.window(msg[1])
                out = ("ok", version, snap)
            elif cmd == "reset":
                replica.reset()
                out = ("ok", replica.version)
            elif cmd == "stats":
                out = ("ok", replica.stats())
            elif cmd == "ping":
                out = ("ok",)
            else:
                out = ("err", f"unknown command {cmd!r}")
        except Exception as e:  # noqa: BLE001 — fail the request, not the loop
            out = ("err", f"{type(e).__name__}: {e}")
        conn.send_bytes(pickle.dumps(out))


class ReplicaProcess:
    """A read replica hosted in its own OS process.

    Same duck-typed surface as :class:`ReplicaEnsemble` (``apply_delta`` /
    ``serve`` / ``stats`` / ``version``), but every call is an RPC over a
    spawn-context pipe, and ``bytes_sent`` counts the real serialized
    payload. One RPC runs at a time per replica (the pipe is the queue);
    parallelism comes from running several replicas.

    Spawn-context caveat: scripts that create ReplicaProcess (directly or
    via ``FleetConfig(transport="proc")``) must do so under an
    ``if __name__ == "__main__":`` guard — the standard multiprocessing
    requirement, since the child re-imports the main module.
    """

    def __init__(
        self,
        name: str,
        workload_name: str,
        build_kw: dict | None = None,
        *,
        micro_batch: int = 64,
        threads: int | None = 1,
        start_timeout_s: float = 120.0,
    ):
        self.name = name
        self.version = 0
        self.bytes_sent = 0
        # Re-entrant: restart() holds it across close() + _spawn() (close
        # acquires it again for the stop handshake) so no concurrent _rpc
        # can interleave with the fresh pipe's "ready" handshake.
        self._lock = threading.RLock()
        self._workload_name = workload_name
        self._build_kw = dict(build_kw or {})
        self._micro_batch = micro_batch
        self._threads = threads
        self._start_timeout_s = start_timeout_s
        self._proc = None
        self._conn = None
        self._spawn()

    def _spawn(self) -> None:
        ctx = mp.get_context("spawn")
        self._conn, child = ctx.Pipe()
        self._proc = ctx.Process(
            target=_replica_worker,
            args=(child, self.name, self._workload_name, dict(self._build_kw),
                  self._micro_batch, self._threads),
            name=f"replica-{self.name}",
            daemon=True,
        )
        self._proc.start()
        child.close()
        if not self._conn.poll(self._start_timeout_s):
            self.close()
            raise TimeoutError(f"replica process {self.name!r} did not start")
        first = pickle.loads(self._conn.recv_bytes())
        if first[0] != "ready":
            self.close()
            raise RuntimeError(f"replica process {self.name!r} failed: {first[1]}")

    def _rpc(self, *msg):
        payload = pickle.dumps(msg, protocol=pickle.HIGHEST_PROTOCOL)
        try:
            with self._lock:
                if self._proc is None or not self._proc.is_alive():
                    raise ReplicaDeadError(
                        f"replica {self.name!r} process is down"
                    )
                self.bytes_sent += len(payload)
                self._conn.send_bytes(payload)
                out = pickle.loads(self._conn.recv_bytes())
        except ReplicaDeadError:
            raise
        except (EOFError, BrokenPipeError, ConnectionResetError, OSError) as e:
            # The transport (not the request) failed — a killed process
            # shows up as EOF on the pipe. Distinct from the worker's
            # ("err", ...) replies, which stay RuntimeError below.
            raise ReplicaDeadError(
                f"replica {self.name!r} transport failed: "
                f"{type(e).__name__}: {e}"
            ) from e
        if out[0] == "err":
            raise RuntimeError(f"replica {self.name!r}: {out[1]}")
        return out

    def apply_delta(self, delta: SnapshotDelta, *, nbytes: int | None = None) -> int:
        nb = nbytes if nbytes is not None else wire_bytes(delta)
        out = self._rpc("delta", delta, nb)
        self.version = out[1]
        return self.version

    def reset(self) -> None:
        out = self._rpc("reset")
        self.version = out[1]

    def serve(self, spec, query_class: str, xs, trace=None):
        """Same contract as :meth:`ReplicaEnsemble.serve`: 2-tuple
        ``(values, staleness_s)``, or a 3-tuple with the worker's spans
        when ``trace`` is passed (the spans are built in the worker
        process — their ``pid`` is the replica's — and ride back inside
        the query reply)."""
        del spec  # resolved registry-side in the worker
        if trace is None:
            out = self._rpc("query", query_class, np.asarray(xs))
            self.version = out[3]
            return out[1], out[2]
        out = self._rpc("query", query_class, np.asarray(xs), tuple(trace))
        self.version = out[3]
        return out[1], out[2], out[4]

    def window(self, known_version: int = -1) -> tuple[int, Snapshot | None]:
        """RPC counterpart of :meth:`ReplicaEnsemble.window`: the snapshot
        crosses the pipe only when ``known_version`` is out of date (numpy
        windows pickle directly)."""
        out = self._rpc("window", known_version)
        self.version = out[1]
        return out[1], out[2]

    def stats(self) -> dict:
        stats = self._rpc("stats")[1]
        stats["bytes_sent"] = self.bytes_sent
        return stats

    # -- chaos / fault-injection surface ------------------------------------

    @property
    def alive(self) -> bool:
        return self._proc is not None and self._proc.is_alive()

    def ping(self) -> bool:
        """True when the worker process answers; False on a dead transport
        (never raises — this is the router's revive() probe)."""
        try:
            self._rpc("ping")
            return True
        except ReplicaDeadError:
            return False

    def kill(self, timeout_s: float = 10.0) -> None:
        """SIGKILL the worker process — the chaos harness's crash. No
        handshake, no cleanup: in-flight RPCs surface ReplicaDeadError."""
        proc = self._proc
        if proc is not None and proc.is_alive():
            proc.kill()
            proc.join(timeout=timeout_s)

    def restart(self) -> None:
        """Respawn the worker in place (fresh interpreter, empty window at
        version 0 — the next sync full-resyncs it). The surrounding lane /
        fleet objects keep their references valid across the bounce.

        Holds the RPC lock for the whole bounce: otherwise a concurrent
        ``_rpc`` (e.g. the fleet's background delta-sync thread) can grab
        the *new* pipe between ``_spawn`` assigning ``self._conn`` and the
        handshake read, consume the worker's ``("ready", ...)`` message,
        and leave its own reply for the handshake to misread. A caller
        blocked in ``_rpc`` on the old pipe fails fast (EOF on the killed
        process -> ReplicaDeadError) and releases the lock, so this cannot
        deadlock."""
        with self._lock:
            self.close(timeout_s=1.0)
            self.version = 0
            self._spawn()

    def close(self, timeout_s: float = 10.0) -> None:
        proc, conn = self._proc, self._conn
        if proc is None:
            return
        try:
            if proc.is_alive():
                try:
                    with self._lock:
                        conn.send_bytes(pickle.dumps(("stop",)))
                        if conn.poll(timeout_s):
                            conn.recv_bytes()
                except (BrokenPipeError, OSError):
                    pass
            proc.join(timeout=timeout_s)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=timeout_s)
        finally:
            conn.close()
            self._proc = None
