"""Snapshot-delta streaming: what a writer sends its read replicas.

A fleet writer's rolling posterior window advances by ``refresh_steps``
draws per refresh while the window itself holds up to ``window`` draws per
chain — so between two syncs only the *tail* of the window is new. A
:class:`SnapshotDelta` carries exactly that tail (plus the refreshed
diagnostics and a staleness stamp) keyed by the writer's monotonically
increasing version (``steps_done``); a replica at ``base_version`` appends
it and trims, reconstructing the writer's window bit for bit. When the gap
exceeds the window depth (cold replica, restore, missed syncs) the delta
degrades to a full-window resync — correctness never depends on the
replica's history, only payload size does.

Payload accounting lives here too: :func:`payload_nbytes` (raw array
bytes) and :func:`wire_bytes` (pickled size — what actually crosses the
process-group pipe in :class:`repro.fleet.replica.ReplicaProcess`), the
numbers ``benchmarks/fleet_bench.py`` reports against the full-snapshot
baseline.
"""
from __future__ import annotations

import pickle
from typing import Any, NamedTuple

import jax
import numpy as np

from ..serving.resident import Snapshot

Params = Any


class SnapshotDelta(NamedTuple):
    """One writer->replica update (all leaves host numpy arrays, picklable)."""

    name: str  # shard name the delta belongs to
    base_version: int  # replica steps_done this applies on top of (0 = full)
    version: int  # writer steps_done after applying
    draws: Params | None  # (K, n_new, ...) new tail of the window; None = empty
    window: int  # rolling-window limit to trim to after appending
    summary: dict  # writer-side ensemble_summary of the last refresh
    staleness_s: float  # age of the newest draw at emission time
    full: bool  # True when draws is the whole window (resync)


def payload_nbytes(tree: Params | None) -> int:
    """Raw bytes of the array payload (0 for an empty delta)."""
    if tree is None:
        return 0
    return int(sum(np.asarray(leaf).nbytes for leaf in jax.tree.leaves(tree)))


def wire_bytes(obj: Any) -> int:
    """Serialized size — the bytes a process-group pipe actually carries."""
    return len(pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL))


def make_delta(
    snap: Snapshot, base_version: int, window: int, name: str = ""
) -> SnapshotDelta:
    """The delta that brings a replica at ``base_version`` up to ``snap``.

    New draws are the last ``snap.steps_done - base_version`` window columns
    (capped at the window depth); when that cap bites — or the replica is
    ahead of the writer, which only happens after a writer restore to an
    older checkpoint — the delta is a full-window resync.
    """
    if snap.draws is None:
        return SnapshotDelta(name, int(base_version), snap.steps_done, None,
                             int(window), snap.summary, snap.staleness_s, False)
    width = int(jax.tree.leaves(snap.draws)[0].shape[1])
    gap = snap.steps_done - base_version
    if gap < 0 or gap >= width or base_version == 0:
        draws = jax.tree.map(np.asarray, snap.draws)
        return SnapshotDelta(name, 0, snap.steps_done, draws, int(window),
                             snap.summary, snap.staleness_s, True)
    if gap == 0:
        return SnapshotDelta(name, int(base_version), snap.steps_done, None,
                             int(window), snap.summary, snap.staleness_s, False)
    draws = jax.tree.map(lambda a: np.asarray(a[:, width - gap:]), snap.draws)
    return SnapshotDelta(name, int(base_version), snap.steps_done, draws,
                         int(window), snap.summary, snap.staleness_s, False)


def apply_delta(window_draws: Params | None, delta: SnapshotDelta) -> Params | None:
    """Fold a delta into a replica's local window; returns the new window.

    Incremental deltas require the replica to sit exactly at
    ``delta.base_version`` — the caller checks that and raises/resyncs —
    this function only performs the append-and-trim (or the full replace).
    """
    if delta.draws is None:
        return window_draws
    if delta.full or window_draws is None:
        return jax.tree.map(lambda a: np.asarray(a)[:, -delta.window:], delta.draws)
    merged = jax.tree.map(
        lambda a, b: np.concatenate([a, np.asarray(b)], axis=1),
        window_draws, delta.draws,
    )
    return jax.tree.map(lambda a: a[:, -delta.window:], merged)
