"""Fleet topology: workload shards, writers, replica pools, and sync.

The sharded serving fleet splits the two halves of posterior serving that
PR 4's single pool fused (the parallel-transition vs replicated-serving
split of Angelino et al., *Patterns of Scalable Bayesian Inference*):

    Fleet
      └─ shard "bayeslr@0"   writer ResidentEnsemble  (advances chains,
      │                       optionally on a 2-d chains x data mesh)
      │     ├─ replica #r0   ReplicaEnsemble | ReplicaProcess
      │     └─ replica #r1     (serve queries from a delta-streamed
      │                          copy of the writer's window)
      └─ shard "bayeslr@1"   ...

Each registered workload gets ``shards`` independent writers — same data,
independent chain keys (``fold_in(seed_key, shard)``), so the fleet's
aggregate posterior capacity scales with shard count — and each writer
broadcasts :mod:`repro.fleet.delta` snapshot deltas to ``replicas`` read
replicas. Writers live in one :class:`repro.serving.EnsemblePool`, so the
freshness policy, warm checkpointing, and background refresh of the
serving layer apply unchanged; replicas resync (a full-window delta) after
a restore and then ride incremental deltas again.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, NamedTuple

import jax

from ..partition.combine import METHODS as COMBINE_METHODS
from ..partition.partitioner import (
    partition_append_indices,
    partition_target,
    take_sections,
)
from ..serving.pool import EnsemblePool, ServingConfig
from ..serving.resident import QuerySpec, ResidentEnsemble
from ..serving.workloads import ServingWorkload, build_serving_workload
from .delta import make_delta, payload_nbytes, wire_bytes
from .replica import ReplicaDeadError, ReplicaEnsemble, ReplicaProcess


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Static fleet shape.

    ``replicas``: read replicas per shard; ``shards``: independent writers
    per workload; ``mesh``: forwarded to every writer's
    ``ChainEnsemble(shard=...)`` (e.g. ``("chains", "data")`` for the 2-d
    fan-out — a no-op on one device); ``transport``: ``"inproc"`` replicas
    share the process (deterministic, cheap — tests/smoke), ``"proc"``
    replicas each get an OS process (the scaling configuration);
    ``sync_interval_s``: pause between background refresh+broadcast rounds;
    ``subposterior``: data-parallel partition count P — each workload's
    observation pool is split into P disjoint stride shards, every writer
    runs against its local slice under the ``p(theta)^(1/P)`` tempered
    prior, and the router recombines the per-partition windows at query
    time with the ``combine`` rule (:mod:`repro.partition`). P=1 is
    bit-for-bit the unpartitioned fleet.
    """

    replicas: int = 2
    shards: int = 1
    serving: ServingConfig = ServingConfig()
    mesh: Any = "auto"
    transport: str = "inproc"  # "inproc" | "proc"
    sync_interval_s: float = 0.0
    # Per-replica XLA intra-op thread cap for the "proc" transport (None =
    # backend default). One thread per replica is what lets N replicas scale
    # across an M-core host instead of contending for one shared pool.
    replica_threads: int | None = 1
    subposterior: int = 1  # data partitions P per workload
    combine: str = "consensus"  # "consensus" | "product" draw combination

    def __post_init__(self):
        if self.replicas < 1 or self.shards < 1:
            raise ValueError("replicas and shards must be >= 1")
        if self.transport not in ("inproc", "proc"):
            raise ValueError(f"unknown transport {self.transport!r}")
        if self.subposterior < 1:
            raise ValueError(
                f"subposterior must be >= 1, got {self.subposterior}"
            )
        if self.combine not in COMBINE_METHODS:
            raise ValueError(
                f"unknown combine method {self.combine!r}; "
                f"known: {COMBINE_METHODS}"
            )


class FleetShard(NamedTuple):
    """One workload shard: a writer and its read replicas."""

    name: str  # "<workload>@<index>" or "<workload>@p<partition>@<index>"
    workload: str
    writer: ResidentEnsemble
    replicas: tuple
    partition: int = 0  # data partition this shard's writer samples


class Fleet:
    """Writers + replicas + delta streams behind one management surface."""

    def __init__(self, config: FleetConfig | None = None):
        self.config = config or FleetConfig()
        self.pool = EnsemblePool(self.config.serving)
        self._workloads: dict[str, ServingWorkload] = {}
        self._shards: dict[str, list[FleetShard]] = {}
        self._partitions: dict[str, int] = {}  # workload -> P
        self._data_sizes: dict[str, int] = {}  # workload -> total sections
        # Replica construction inputs, kept for runtime scale-out: the
        # workload builder kwargs add_replica re-plays, and a per-shard
        # monotonic name counter so a retired replica's name is never
        # reused (lane/trace history stays unambiguous).
        self._build_kw: dict[str, dict] = {}
        self._replica_seq: dict[str, int] = {}
        self._sync_lock = threading.Lock()
        self.sync_stats = {
            "syncs": 0,
            "delta_wire_bytes": 0,
            "full_wire_bytes": 0,  # what full-snapshot streaming would cost
            "delta_payload_bytes": 0,
            "full_payload_bytes": 0,
            "full_deltas": 0,  # syncs that were full-window resyncs
            "skipped_dead": 0,  # replicas skipped because their transport was down
        }
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        # Last background refresh+broadcast error per shard (cleared on the
        # next success) — surfaced in report() so a dying replica shows up
        # instead of silently freezing the shard's delta stream.
        self._shard_errors: dict[str, str] = {}

    # -- registration ------------------------------------------------------

    def add_workload(self, name: str, **build_kw) -> list[FleetShard]:
        """Register ``shards`` writers + ``replicas`` replicas for a
        registry workload. ``build_kw`` reaches the workload builder
        (every shard gets the same data; chain keys differ per shard).

        With ``config.subposterior = P > 1`` the workload's observation pool
        is partitioned first and each of the P partitions gets its own
        ``shards`` writers (P × shards writers total), named
        ``"<workload>@p<partition>@<index>"``. The P=1 path is untouched —
        same shard names, same keys, same targets as an unpartitioned
        fleet.
        """
        if name in self._shards:
            raise ValueError(f"workload {name!r} already in this fleet")
        cfg = self.config
        scfg = cfg.serving
        build_kw.setdefault("num_chains", scfg.num_chains)
        build_kw.setdefault("seed", scfg.seed)
        base = build_serving_workload(name, **build_kw)
        self._workloads[name] = base
        self._build_kw[name] = dict(build_kw)
        if cfg.subposterior > 1:
            return self._add_partitioned(name, base, build_kw)
        shards: list[FleetShard] = []
        for i in range(cfg.shards):
            shard_name = f"{name}@{i}"  # "@": shard names double as checkpoint file stems
            ensemble = base.ensemble
            if cfg.mesh != "auto":
                ensemble = dataclasses.replace(ensemble, shard=cfg.mesh)
            shard_wl = dataclasses.replace(
                base, name=shard_name, ensemble=ensemble
            )
            writer = self.pool.add_workload(
                shard_wl, key=jax.random.fold_in(jax.random.key(scfg.seed), i)
            )
            replicas = tuple(
                self._make_replica(f"{shard_name}#r{j}", name, build_kw)
                for j in range(cfg.replicas)
            )
            self._replica_seq[shard_name] = cfg.replicas
            shards.append(FleetShard(shard_name, name, writer, replicas))
        self._shards[name] = shards
        self._partitions[name] = 1
        if base.ensemble.target is not None:
            self._data_sizes[name] = int(base.ensemble.target.num_sections)
        return shards

    def _add_partitioned(
        self, name: str, base: ServingWorkload, build_kw: dict
    ) -> list[FleetShard]:
        """The subposterior fan-out: P tempered slice targets, each with its
        own writer group. Raises for workloads whose target carries no
        :class:`~repro.core.target_builder.TargetSpec` recipe (composite /
        latent-variable transitions cannot be data-partitioned)."""
        cfg = self.config
        scfg = cfg.serving
        num_p = cfg.subposterior
        if base.ensemble.target is None:
            raise ValueError(
                f"workload {name!r} runs a composite transition with no "
                "single target; subposterior partitioning needs a "
                "builder-constructed target"
            )
        sub_targets = partition_target(base.ensemble.target, num_p)
        shards: list[FleetShard] = []
        for p in range(num_p):
            for i in range(cfg.shards):
                shard_name = f"{name}@p{p}@{i}"
                ensemble = dataclasses.replace(
                    base.ensemble, target=sub_targets[p]
                )
                if cfg.mesh != "auto":
                    ensemble = dataclasses.replace(ensemble, shard=cfg.mesh)
                shard_wl = dataclasses.replace(
                    base, name=shard_name, ensemble=ensemble
                )
                # Independent chain trajectories per (partition, shard):
                # fold the partition in first so partition p shard i never
                # collides with partition i shard p.
                key = jax.random.fold_in(
                    jax.random.fold_in(jax.random.key(scfg.seed), p), i
                )
                writer = self.pool.add_workload(shard_wl, key=key)
                replicas = tuple(
                    self._make_replica(f"{shard_name}#r{j}", name, build_kw)
                    for j in range(cfg.replicas)
                )
                self._replica_seq[shard_name] = cfg.replicas
                shards.append(
                    FleetShard(shard_name, name, writer, replicas, p)
                )
        self._shards[name] = shards
        self._partitions[name] = num_p
        self._data_sizes[name] = int(base.ensemble.target.num_sections)
        return shards

    def _make_replica(self, replica_name: str, workload: str, build_kw: dict):
        if self.config.transport == "proc":
            return ReplicaProcess(
                replica_name, workload, build_kw,
                micro_batch=self.config.serving.micro_batch,
                threads=self.config.replica_threads,
            )
        return ReplicaEnsemble(
            replica_name, micro_batch=self.config.serving.micro_batch
        )

    # -- lookups -----------------------------------------------------------

    def workloads(self) -> tuple[str, ...]:
        return tuple(sorted(self._shards))

    def shards(self, workload: str) -> list[FleetShard]:
        return self._shards[workload]

    def workload(self, name: str) -> ServingWorkload:
        return self._workloads[name]

    def spec(self, workload: str, query_class: str) -> QuerySpec:
        return self._workloads[workload].query_specs[query_class]

    def num_partitions(self, workload: str) -> int:
        """Data partitions P the workload was registered with (1 when the
        fleet is unpartitioned)."""
        return self._partitions.get(workload, 1)

    def replica_count(self, workload: str) -> int:
        """Live replica total across the workload's shards."""
        return sum(len(s.replicas) for s in self._shards[workload])

    # -- runtime scaling ---------------------------------------------------

    def add_replica(self, workload: str, shard_index: int = 0):
        """Spawn one more read replica on a running shard (what the
        autoscaler actuates through).

        The replica is built exactly like its launch-time siblings (same
        transport, same builder kwargs, the shard's next never-reused
        ``#rN`` name), the shard entry is swapped for one whose ``replicas``
        tuple includes it, and one :meth:`sync_shard` round seeds it — a
        version-0 replica receives the full window, so it serves bit-exact
        with the writer before this method returns. The background sync
        loop re-reads its shard every round, so subsequent deltas reach the
        newcomer without a restart. Returns ``(shard, replica)`` with the
        updated shard — hand both to
        :meth:`repro.fleet.FleetRouter.attach_lane` to start routing to it.
        """
        shards = self._shards[workload]
        shard = shards[shard_index]
        seq = self._replica_seq.get(shard.name, len(shard.replicas))
        self._replica_seq[shard.name] = seq + 1
        replica = self._make_replica(
            f"{shard.name}#r{seq}", workload, self._build_kw.get(workload, {})
        )
        new_shard = shard._replace(replicas=shard.replicas + (replica,))
        shards[shard_index] = new_shard
        self.sync_shard(new_shard)  # join resync: version 0 -> full window
        return new_shard, replica

    def remove_replica(self, workload: str, replica_name: str | None = None):
        """Retire one replica (the scale-down actuation): drop it from its
        shard's broadcast set, then close its transport.

        Detach its router lane **first** (:meth:`FleetRouter.detach_lane`
        reroutes the backlog and waits out the in-flight batch) — this
        method closes the replica immediately after unlinking it. With no
        ``replica_name`` the newest replica of the first shard is retired.
        Each shard keeps at least one replica. Returns the retired
        replica's name."""
        shards = self._shards[workload]
        if replica_name is None:
            shard_index, shard = 0, shards[0]
            replica = shard.replicas[-1]
        else:
            for shard_index, shard in enumerate(shards):
                replica = next(
                    (r for r in shard.replicas if r.name == replica_name),
                    None,
                )
                if replica is not None:
                    break
            else:
                raise KeyError(
                    f"no replica {replica_name!r} in workload {workload!r}"
                )
        if len(shard.replicas) <= 1:
            raise ValueError(
                f"cannot remove the last replica of shard {shard.name!r}"
            )
        remaining = tuple(r for r in shard.replicas if r is not replica)
        with self._sync_lock:  # never yank a replica mid-broadcast
            shards[shard_index] = shard._replace(replicas=remaining)
        self._shard_errors.pop(f"{shard.name}/{replica.name}", None)
        replica.close()
        return replica.name

    # -- streaming append --------------------------------------------------

    def append_observations(self, workload: str, new_data) -> int:
        """Fold a freshly appended observation chunk into every running
        writer of ``workload`` (the streaming append-only target mode).

        Unpartitioned (P=1): every shard's writer sees the full chunk —
        shards sample the same grown posterior. Partitioned: the chunk is
        routed with :func:`~repro.partition.partitioner.partition_append_indices`,
        so each partition's slice grows exactly as if the concatenated pool
        had been stride-partitioned from scratch (no repartitioning, chains
        keep running). Writers that receive rows reset their staleness
        clock (:meth:`~repro.serving.resident.ResidentEnsemble.append`), so
        pre-append windows stop serving as fresh. Returns the number of
        appended sections.
        """
        shards = self._shards[workload]
        num_p = self._partitions.get(workload, 1)
        leaves = jax.tree.leaves(new_data)
        if not leaves:
            raise ValueError("empty append chunk (no array leaves)")
        n_new = int(leaves[0].shape[0])
        if n_new == 0:
            return 0
        if num_p == 1:
            for shard in shards:
                shard.writer.append(new_data)
        else:
            parts = partition_append_indices(
                self._data_sizes[workload], n_new, num_p
            )
            for shard in shards:
                idx = parts[shard.partition]
                if idx.shape[0]:
                    shard.writer.append(take_sections(new_data, idx))
        self._data_sizes[workload] = self._data_sizes.get(workload, 0) + n_new
        return n_new

    # -- delta streaming ---------------------------------------------------

    def sync_shard(self, shard: FleetShard) -> int:
        """Broadcast the writer's snapshot to every replica as deltas;
        returns total wire bytes sent. Also accounts what streaming the full
        window instead would have cost (the bench's comparison)."""
        snap = shard.writer.snapshot()
        window = shard.writer.window
        sent = 0
        with self._sync_lock:
            for replica in shard.replicas:
                try:
                    delta = make_delta(snap, replica.version, window, shard.name)
                    nbytes = wire_bytes(delta)
                    try:
                        replica.apply_delta(delta, nbytes=nbytes)
                    except (ValueError, RuntimeError):
                        # Version drift (e.g. a replica reset raced the
                        # snapshot): fall back to a full resync. ReplicaProcess
                        # surfaces the worker's ValueError as RuntimeError, so
                        # both are resync triggers; a genuinely broken replica
                        # raises again below and propagates.
                        delta = make_delta(snap, 0, window, shard.name)
                        nbytes = wire_bytes(delta)
                        replica.apply_delta(delta, nbytes=nbytes)
                except ReplicaDeadError as e:
                    # A crashed replica must not stall the broadcast to its
                    # healthy peers: skip it (the router routes around the
                    # dead lane) and keep the error visible until a later
                    # sync — after restart() — reaches it again.
                    self.sync_stats["skipped_dead"] += 1
                    self._shard_errors[f"{shard.name}/{replica.name}"] = (
                        f"{type(e).__name__}: {e}"
                    )
                    continue
                self._shard_errors.pop(f"{shard.name}/{replica.name}", None)
                delta_payload = payload_nbytes(delta.draws)
                if delta.full:
                    full_wire, full_payload = nbytes, delta_payload
                else:
                    # The full-snapshot baseline without serializing the
                    # whole window every sync just for accounting: the
                    # pickle frame (name, summary, ints) is shared between
                    # the delta and its full-window counterpart, so the
                    # full wire cost is the delta's plus the payload
                    # difference. Exact for the raw-array part, which is
                    # what dominates.
                    full_payload = payload_nbytes(snap.draws)
                    full_wire = nbytes + (full_payload - delta_payload)
                self.sync_stats["syncs"] += 1
                self.sync_stats["full_deltas"] += int(delta.full)
                self.sync_stats["delta_wire_bytes"] += nbytes
                self.sync_stats["delta_payload_bytes"] += delta_payload
                self.sync_stats["full_wire_bytes"] += full_wire
                self.sync_stats["full_payload_bytes"] += full_payload
                sent += nbytes
        return sent

    def sync_all(self) -> int:
        return sum(
            self.sync_shard(s) for shards in self._shards.values() for s in shards
        )

    def pump(self, workload: str | None = None) -> None:
        """One refresh+broadcast round (synchronous — what tests and the
        smoke path drive; ``start`` moves the same loop onto threads)."""
        names = [workload] if workload else list(self._shards)
        for name in names:
            for shard in self._shards[name]:
                shard.writer.refresh()
                self.sync_shard(shard)

    # -- lifecycle ---------------------------------------------------------

    def warm(self) -> None:
        """Bring every writer to a servable snapshot, then seed every
        replica with its first (full) delta."""
        self.pool.warm()
        self.sync_all()

    def start(self) -> None:
        """Background refresh+broadcast, one thread per shard."""
        if self._threads:
            return
        self._stop.clear()
        for name, shards in self._shards.items():
            for idx, shard in enumerate(shards):
                def loop(name=name, idx=idx):
                    while not self._stop.is_set():
                        # Re-read the shard entry every round: add_replica /
                        # remove_replica swap it for one with an updated
                        # replicas tuple, and a loop pinned to the launch-
                        # time NamedTuple would never broadcast to a
                        # runtime-attached replica.
                        shard = self._shards[name][idx]
                        try:
                            shard.writer.refresh()
                            self.sync_shard(shard)
                            self._shard_errors.pop(shard.name, None)
                        except Exception as e:  # noqa: BLE001 — a dead
                            # replica must not silently kill the shard's
                            # refresh loop; record, back off, retry (the
                            # error stays visible in report() until a sync
                            # succeeds).
                            self._shard_errors[shard.name] = (
                                f"{type(e).__name__}: {e}"
                            )
                            self._stop.wait(0.5)
                            continue
                        if self.config.sync_interval_s:
                            self._stop.wait(self.config.sync_interval_s)

                t = threading.Thread(
                    target=loop, name=f"fleet-{shard.name}", daemon=True
                )
                t.start()
                self._threads.append(t)

    def stop(self, timeout_s: float = 30.0) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=timeout_s)
        self._threads = []

    def close(self) -> None:
        """Stop background sync and tear down replica processes."""
        self.stop()
        for shards in self._shards.values():
            for shard in shards:
                for replica in shard.replicas:
                    replica.close()

    # -- persistence -------------------------------------------------------

    def save(self, ckpt_dir: str, keep: int = 3) -> str:
        """Persist every writer (replicas are derived state: they resync)."""
        return self.pool.save(ckpt_dir, keep=keep)

    def restore(self, ckpt_dir: str, step: int | None = None) -> int:
        """Restore writers warm, then full-resync every replica — the
        restored key schedule continues exactly (writer contract), and the
        replicas mirror the restored windows."""
        step = self.pool.restore(ckpt_dir, step=step)
        for shards in self._shards.values():
            for shard in shards:
                for replica in shard.replicas:
                    replica.reset()
                self.sync_shard(shard)
        return step

    # -- reporting ---------------------------------------------------------

    def report(self) -> dict:
        out = {"sync": dict(self.sync_stats), "shards": {},
               "errors": dict(self._shard_errors)}
        for name, shards in sorted(self._shards.items()):
            for shard in shards:
                out["shards"][shard.name] = {
                    "writer_steps": shard.writer.steps_done,
                    "replica_versions": [r.version for r in shard.replicas],
                    "replicas": [self._replica_stats(r) for r in shard.replicas],
                }
        return out

    @staticmethod
    def _replica_stats(replica) -> dict:
        try:
            return replica.stats()
        except ReplicaDeadError:
            return {"name": replica.name, "alive": False}
