"""Sharded serving fleet: writers, delta-streamed replicas, admission control.

The scale-out layer over :mod:`repro.serving` (see docs/ARCHITECTURE.md):

    FleetRouter ─▶ replica lanes ─▶ ReplicaEnsemble/-Process ─▶ values
     priorities     least-loaded      local window copy
     admission      per workload        ▲ SnapshotDelta stream
     shed/admit       shard             │ (new draws only)
                                   ResidentEnsemble writers
                                   (EnsemblePool: freshness,
                                    checkpoints, 2-d mesh runs)

Front-end: ``python -m repro.launch.serve --fleet --workload bayeslr``.
Closing the loop, :mod:`.autoscale` turns the recorded admission/SLO
signals back into replica adds/retires (``--autoscale``).
"""
from .autoscale import AutoScaleConfig, AutoScaler
from .delta import SnapshotDelta, apply_delta, make_delta, payload_nbytes, wire_bytes
from .replica import ReplicaDeadError, ReplicaEnsemble, ReplicaProcess
from .router import AdmissionConfig, FleetRouter
from .topology import Fleet, FleetConfig, FleetShard

__all__ = [
    "AdmissionConfig",
    "AutoScaleConfig",
    "AutoScaler",
    "Fleet",
    "FleetConfig",
    "FleetRouter",
    "FleetShard",
    "ReplicaDeadError",
    "ReplicaEnsemble",
    "ReplicaProcess",
    "SnapshotDelta",
    "apply_delta",
    "make_delta",
    "payload_nbytes",
    "wire_bytes",
]
