"""Fault-tolerant runtime loops."""
from .train_loop import InjectedFailure, LoopConfig, PreemptionRequested, run_loop

__all__ = ["InjectedFailure", "LoopConfig", "PreemptionRequested", "run_loop"]
