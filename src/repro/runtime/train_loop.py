"""Fault-tolerant chain/train loop.

Chain state is tiny and exact: (step, params, acceptance stats) — the RNG is
counter-based (fold_in(base, step)), so resume needs no RNG state at all and
a restarted run reproduces the original trajectory bit-for-bit (tested).
Preemption: SIGTERM/flag-file triggers a final checkpoint and a clean exit;
any accepted transition is a consistent state, so there is no in-flight
window to lose beyond the current step. Straggler mitigation at the
algorithm level: ``round_deadline`` caps sequential-test rounds per
transition (the test just decides on the evidence it has — a bounded-staleness
knob unavailable to SGD).
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Any, Callable

import jax
import numpy as np

from ..checkpoint import manager as ckpt


@dataclasses.dataclass
class LoopConfig:
    num_steps: int
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    seed: int = 0
    preempt_flag: str | None = None  # touch this file to request clean stop
    fail_at_step: int | None = None  # fault-injection hook for tests


class PreemptionRequested(Exception):
    pass


class InjectedFailure(Exception):
    pass


def run_loop(
    step_fn: Callable,  # (key, params, batch) -> (params, info)
    params: Any,
    batch_fn: Callable[[int], Any],
    cfg: LoopConfig,
    collect: Callable[[Any, Any], Any] | None = None,
) -> dict:
    """Drive transitions with periodic checkpointing and deterministic resume.

    Returns {params, step, infos, samples}."""
    start_step = 0
    latest = ckpt.latest_step(cfg.ckpt_dir)
    if latest is not None:
        start_step, params = ckpt.restore(cfg.ckpt_dir, latest, target=params)
        start_step = int(start_step) + 1

    stop = {"flag": False}

    def _sigterm(signum, frame):  # pragma: no cover - signal path
        stop["flag"] = True

    old = signal.signal(signal.SIGTERM, _sigterm)
    base_key = jax.random.key(cfg.seed)
    infos, samples = [], []
    try:
        for step in range(start_step, cfg.num_steps):
            if cfg.fail_at_step is not None and step == cfg.fail_at_step:
                raise InjectedFailure(f"injected failure at step {step}")
            if stop["flag"] or (
                cfg.preempt_flag and os.path.exists(cfg.preempt_flag)
            ):
                ckpt.save(cfg.ckpt_dir, step - 1, params, keep=cfg.keep)
                raise PreemptionRequested(f"preempted before step {step}")
            key = jax.random.fold_in(base_key, step)
            params, info = step_fn(key, params, batch_fn(step))
            infos.append({k: np.asarray(v) for k, v in info._asdict().items()})
            if collect is not None:
                samples.append(collect(params, info))
            if (step + 1) % cfg.ckpt_every == 0 or step == cfg.num_steps - 1:
                ckpt.save(cfg.ckpt_dir, step, params, keep=cfg.keep)
        return {"params": params, "step": cfg.num_steps - 1, "infos": infos, "samples": samples}
    finally:
        signal.signal(signal.SIGTERM, old)


def wall_clock_step_stats(step_fn, args, n: int = 5) -> dict:
    """Utility for benchmarks: compile once, then time n executions."""
    out = step_fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        out = step_fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return {"mean_s": float(np.mean(times)), "min_s": float(np.min(times))}
