"""Joint DP mixture of logistic experts (paper Sec. 4.2) on synthetic data.

CRP Gibbs for assignments + MH for alpha + subsampled MH for each expert's
weights — the inference program of paper Fig. 7 (top), expressed as a
composite cycle and run as K independent replicas on the multi-chain
ensemble engine (one jitted program advances every replica; the w-moves'
dynamic-pool austerity amortizes across replicas).

    PYTHONPATH=src python examples/dpmixture.py            # full size
    PYTHONPATH=src python examples/dpmixture.py --smoke    # CI-sized
"""
import argparse
import time

import jax
import numpy as np

from repro.experiments import jointdpm


def main(smoke: bool = False):
    cfg = jointdpm.JDPMConfig()
    if smoke:
        n, n_test, replicas, cycles, w_moves = 800, 200, 2, 8, 5
    else:
        n, n_test, replicas, cycles, w_moves = 4000, 1000, 4, 30, 10
    data = jointdpm.synth(jax.random.key(0), n=n, n_test=n_test)

    from repro.kernels import ops
    print(ops.dispatch_summary())
    print(f"jointDPM N={n}: {replicas} replicas x {cycles} cycles of "
          f"(mh-alpha, gibbs-z, {w_moves} subsampled-mh-w moves)")
    t0 = time.perf_counter()
    state, samples, infos, diag = jointdpm.run_posterior_ensemble(
        jax.random.key(2), data, cfg, num_chains=replicas, num_cycles=cycles,
        batch_size=100, epsilon=0.3, sigma_prop=0.3, w_moves=w_moves,
    )
    wall = time.perf_counter() - t0

    # posterior-predictive accuracy of each replica's final state
    accs = []
    for k in range(replicas):
        st_k = jax.tree.map(lambda l: l[k], state.theta)
        prob = jointdpm.predict_proba(st_k, data.x_test, cfg)
        accs.append(jointdpm.accuracy(np.asarray(prob), np.asarray(data.y_test)))
    print(f"  wall time          : {wall:.1f}s "
          f"({replicas * cycles / wall:.1f} cycles/sec aggregate)")
    print(f"  accuracy/replica   : {np.round(accs, 3)}")
    print(f"  active clusters    : {diag['k_active_final']}")
    print(f"  w accept rate      : {np.round(diag['w_accept_rate'], 2)}")
    print(f"  w sections touched : {diag['w_frac_evaluated']:.1%} of each expert's "
          f"members per move")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (seconds instead of minutes)")
    main(smoke=ap.parse_args().smoke)
