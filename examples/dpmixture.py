"""Joint DP mixture of logistic experts (paper Sec. 4.2) on synthetic data.

CRP Gibbs for assignments + MH for alpha + subsampled MH for each expert's
weights — the inference program of paper Fig. 7 (top), expressed with the
kernel combinators.

    PYTHONPATH=src python examples/dpmixture.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.experiments import jointdpm
from repro.inference import Cycle, run_inference


def main():
    cfg = jointdpm.JDPMConfig()
    data = jointdpm.synth(jax.random.key(0), n=4000, n_test=1000)
    state0 = jointdpm.init_state(jax.random.key(1), data, cfg)
    n = data.x.shape[0]

    gz = jax.jit(lambda k, s, p: jointdpm.gibbs_z_steps(k, s, data, cfg, p))
    mw = jax.jit(lambda k, s: jointdpm.subsampled_mh_w(
        k, s, data, cfg, batch_size=100, epsilon=0.3, sigma_prop=0.3))

    # the paper's program: (cycle ((mh alpha ...) (gibbs z ...) (subsampled_mh w ...)))
    def alpha_kernel(key, st):
        return {"s": jointdpm.mh_alpha(key, st["s"], cfg)}

    def z_kernel(key, st):
        pts = jax.random.permutation(key, n)[: n // 2]
        return {"s": gz(key, st["s"], pts)}

    def w_kernel(key, st):
        s = st["s"]
        for j in range(10):
            s, _ = mw(jax.random.fold_in(key, j), s)
        return {"s": s}

    program = Cycle([alpha_kernel, z_kernel, w_kernel])

    t0 = time.perf_counter()
    accs = []

    def callback(it, st):
        if it % 5 == 0:
            prob = jointdpm.predict_proba(st["s"], data.x_test, cfg)
            acc = jointdpm.accuracy(np.asarray(prob), np.asarray(data.y_test))
            accs.append(acc)
            k_act = int(jnp.sum(st["s"].stats.n > 0.5))
            print(f"  cycle {it:3d}: accuracy={acc:.3f} clusters={k_act} "
                  f"alpha={float(st['s'].alpha):.2f} t={time.perf_counter() - t0:.0f}s")

    state = run_inference(jax.random.key(2), {"s": state0}, program, 30, callback)
    prob = jointdpm.predict_proba(state["s"], data.x_test, cfg)
    print(f"final accuracy: {jointdpm.accuracy(np.asarray(prob), np.asarray(data.y_test)):.3f}")


if __name__ == "__main__":
    main()
