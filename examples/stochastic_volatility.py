"""Stochastic volatility: joint state + parameter estimation (paper Sec 4.3).

Particle Gibbs (conditional SMC) samples the latent log-volatility paths;
subsampled MH samples (phi, sigma^2) with *dependent* local sections (the
h-transition factors). The whole program — pgibbs sweep cycled with the two
parameter moves — runs as a composite cycle on the multi-chain ensemble
engine: K chains advance inside one jitted program and the parameter moves'
sequential-test rounds evaluate (K, m) blocks through the fused
``gaussian_ar1`` kernel family when dispatch selects it.

    PYTHONPATH=src python examples/stochastic_volatility.py            # full size
    PYTHONPATH=src python examples/stochastic_volatility.py --smoke    # CI-sized
"""
import argparse
import time

import jax
import numpy as np

from repro.experiments import stochvol


def main(smoke: bool = False):
    true_phi, true_sigma = 0.95, 0.1
    if smoke:
        series, length, chains, iters, particles = 60, 5, 2, 60, 10
    else:
        series, length, chains, iters, particles = 200, 5, 4, 400, 25
    data = stochvol.synth(jax.random.key(0), num_series=series, length=length,
                          phi=true_phi, sigma=true_sigma)
    n = data.obs.size

    from repro.kernels import ops
    print(ops.dispatch_summary()
          + f" sweep={stochvol.resolve_sweep()}")
    print(f"stochvol S={series} T={length} ({n} transition factors): "
          f"{chains} chains x {iters} cycles of (pgibbs, mh-phi, mh-sigma2)")
    t0 = time.perf_counter()
    state, samples, infos, diag = stochvol.run_posterior_ensemble(
        jax.random.key(1), data, num_chains=chains, num_steps=iters,
        batch_size=100, epsilon=0.01, num_particles=particles,
    )
    wall = time.perf_counter() - t0

    burn = iters // 3
    phis = np.asarray(samples["phi"])[:, burn:]
    sigmas = np.sqrt(np.asarray(samples["sigma2"])[:, burn:])
    print(f"  wall time        : {wall:.1f}s "
          f"({chains * iters / wall:.0f} cycles/sec aggregate)")
    print(f"  posterior phi    : {phis.mean():.3f} ± {phis.std():.3f} (true {true_phi})")
    print(f"  posterior sigma  : {sigmas.mean():.3f} ± {sigmas.std():.3f} (true {true_sigma})")
    print(f"  split R-hat      : phi={diag['rhat_phi']:.3f} "
          f"sigma2={diag['rhat_sigma2']:.3f}")
    frac = diag["frac_evaluated"]
    print(f"  sections touched : phi={frac['phi']:.1%} sigma2={frac['sigma2']:.1%} "
          f"of {n} transition factors per move")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (seconds instead of minutes)")
    main(smoke=ap.parse_args().smoke)
