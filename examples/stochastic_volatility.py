"""Stochastic volatility: joint state + parameter estimation (paper Sec 4.3).

Particle Gibbs (conditional SMC) samples the latent log-volatility paths;
subsampled MH samples (phi, sigma^2) with *dependent* local sections (the
h-transition factors).

    PYTHONPATH=src python examples/stochastic_volatility.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SubsampledMHConfig, make_sampler, subsampled_mh_step
from repro.experiments import stochvol


def main():
    true_phi, true_sigma = 0.95, 0.1
    data = stochvol.synth(jax.random.key(0), num_series=200, length=5,
                          phi=true_phi, sigma=true_sigma)
    theta = {"phi": jnp.asarray(0.7), "sigma2": jnp.asarray(0.03)}
    h = jnp.zeros_like(data.obs)
    cfg = SubsampledMHConfig(batch_size=100, epsilon=0.01)

    pg = jax.jit(lambda k, h, t: stochvol.pgibbs_sweep(
        k, data.obs, h, stochvol.SVParams(t["phi"], t["sigma2"]), 25))

    target0 = stochvol.make_param_target(h, "phi")
    s0, reset, draw = make_sampler("fy", target0.num_sections)

    def make_step(leaf, sig):
        def f(k, th, hh):
            t = stochvol.make_param_target(hh, leaf)
            return subsampled_mh_step(k, th, s0, t, stochvol.SingleLeafRW(leaf, sig),
                                      cfg, reset, draw)
        return jax.jit(f)

    phi_step, sig_step = make_step("phi", 0.02), make_step("sigma2", 0.003)

    phis, sig2s, fracs = [], [], []
    key = jax.random.key(1)
    t0 = time.perf_counter()
    iters = 400
    for it in range(iters):
        key, k1, k2, k3 = jax.random.split(key, 4)
        h = pg(k1, h, theta)  # particle Gibbs over states
        theta, _, i1 = phi_step(k2, theta, h)
        theta, _, i2 = sig_step(k3, theta, h)
        phis.append(float(theta["phi"]))
        sig2s.append(float(theta["sigma2"]))
        fracs.append((int(i1.n_evaluated) + int(i2.n_evaluated)) / (2 * target0.num_sections))
        if (it + 1) % 100 == 0:
            print(f"  iter {it + 1}: phi={phis[-1]:.3f} sigma={np.sqrt(sig2s[-1]):.3f} "
                  f"frac_evaluated={np.mean(fracs[-100:]):.1%} "
                  f"t={time.perf_counter() - t0:.0f}s")

    burn = iters // 3
    print(f"\nposterior phi   : {np.mean(phis[burn:]):.3f} ± {np.std(phis[burn:]):.3f} "
          f"(true {true_phi})")
    print(f"posterior sigma : {np.mean(np.sqrt(sig2s[burn:])):.3f} ± "
          f"{np.std(np.sqrt(sig2s[burn:])):.3f} (true {true_sigma})")
    print(f"mean fraction of transition factors evaluated: {np.mean(fracs):.1%}")


if __name__ == "__main__":
    main()
