"""Posterior-predictive serving: batched prefill + decode from a parameter
sample (checkpoint or fresh init).

    PYTHONPATH=src python examples/serve_lm.py --arch chatglm3-6b --reduced
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduce_config
from repro.models import decode_step, init_params, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b", choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduce_config(cfg)
    print(f"serving {cfg.name}: batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen_len}")
    params = init_params(jax.random.key(0), cfg)
    prompts = jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )
    extra = None
    if cfg.family == "audio":
        extra = {"frames": 0.1 * jax.random.normal(
            jax.random.key(2), (args.batch, cfg.n_audio_frames, cfg.d_model),
            jnp.bfloat16)}

    max_len = args.prompt_len + args.gen_len + 8
    jprefill = jax.jit(lambda p, t: prefill(p, t, cfg, max_len, extra))
    jdecode = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))

    t0 = time.perf_counter()
    cache, logits = jprefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    key = jax.random.key(3)
    tokens = []
    tok = jnp.argmax(logits, -1)[:, None]
    t0 = time.perf_counter()
    for i in range(args.gen_len):
        key, sub = jax.random.split(key)
        cache, logits = jdecode(params, cache, tok)
        tok = jax.random.categorical(sub, logits / args.temperature, axis=-1)[:, None]
        tokens.append(np.asarray(tok[:, 0]))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    gen = np.stack(tokens, 1)
    print(f"prefill: {t_prefill:.2f}s  "
          f"({args.batch * args.prompt_len / t_prefill:.0f} tok/s)")
    print(f"decode : {t_decode:.2f}s  "
          f"({args.batch * args.gen_len / t_decode:.0f} tok/s, "
          f"{1e3 * t_decode / args.gen_len:.1f} ms/step)")
    print(f"sample token ids (request 0): {gen[0][:16]}")


if __name__ == "__main__":
    main()
