"""End-to-end driver: train a small LM, then run Bayesian inference over a
parameter block with subsampled MH (hybrid inference: SGD substrate + MH,
the paper's "interoperates with other general-purpose inference").

Phase 1 — Adam on Markov-chain synthetic data for a few hundred steps
          (loss curve printed).
Phase 2 — subsampled-MH posterior sampling over the final-norm block with
          the trained weights as the likelihood backbone; reports acceptance,
          fraction of the pool evaluated per transition, and the exact-MH
          comparison.

    PYTHONPATH=src python examples/lm_train.py            # ~8M params
    PYTHONPATH=src python examples/lm_train.py --preset 100m --steps 300
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.bayes import TrainConfig, make_exact_step, make_train_step
from repro.checkpoint import manager as ckpt
from repro.data import DataConfig, MarkovStream
from repro.models import init_params
from repro.models.transformer import ModelConfig
from repro.optim import adam_init, adam_step, lm_loss_fn
from repro.runtime import LoopConfig, run_loop

PRESETS = {
    "small": ModelConfig(name="lm-small", family="dense", n_layers=4, d_model=256,
                         n_heads=8, n_kv=4, d_ff=1024, vocab=2048, max_seq=256),
    "100m": ModelConfig(name="lm-100m", family="dense", n_layers=12, d_model=768,
                        n_heads=12, n_kv=12, d_ff=3072, vocab=8192, max_seq=1024),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--mh-steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="artifacts/lm_train_ckpt")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    print(f"model: {cfg.name} ({cfg.param_count() / 1e6:.1f}M params)")
    params = init_params(jax.random.key(0), cfg)
    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch, seed=0)
    stream = MarkovStream(data, concentration=0.2)

    # ---- Phase 1: Adam substrate ------------------------------------------
    loss_fn = lm_loss_fn(cfg)
    vg = jax.jit(jax.value_and_grad(loss_fn))
    opt = adam_init(params)
    t0 = time.perf_counter()
    for step in range(args.steps):
        loss, grads = vg(params, stream.batch(step))
        params, opt = adam_step(grads, opt, params, lr=2e-3)
        if step % max(args.steps // 10, 1) == 0 or step == args.steps - 1:
            print(f"  adam step {step:4d}: loss/token={float(loss):.4f} "
                  f"t={time.perf_counter() - t0:.0f}s")
    ckpt.save(args.ckpt_dir, args.steps, params)
    print(f"checkpoint saved to {args.ckpt_dir}")

    # ---- Phase 2: subsampled MH over the final-norm block ------------------
    print("\nBayesian block inference (subsampled MH over 'final_norm'):")
    pool_batch = stream.batch(10_001)  # held-out pool of sequences
    for name, maker, tc in [
        ("subsampled", make_train_step,
         TrainConfig(round_batch=4, epsilon=0.05, sigma=5e-3,
                     propose_paths=("final_norm",))),
        ("exact", make_exact_step,
         TrainConfig(round_batch=4, sigma=5e-3, propose_paths=("final_norm",))),
    ]:
        step_fn = jax.jit(maker(cfg, tc))
        th = params
        acc, n_eval, t0 = [], [], time.perf_counter()
        for i in range(args.mh_steps):
            th, info = step_fn(jax.random.fold_in(jax.random.key(7), i), th, pool_batch)
        jax.block_until_ready(jax.tree.leaves(th)[0])
        wall = time.perf_counter() - t0
        # re-run collecting stats (cheap; jit cached)
        th = params
        for i in range(args.mh_steps):
            th, info = step_fn(jax.random.fold_in(jax.random.key(7), i), th, pool_batch)
            acc.append(bool(info.accepted))
            n_eval.append(int(info.n_evaluated))
        print(f"  {name:10s}: acceptance={np.mean(acc):.2f} "
              f"sections/transition={np.mean(n_eval):.1f}/{args.batch} "
              f"wall={wall:.1f}s ({1e3 * wall / args.mh_steps:.0f} ms/transition)")


if __name__ == "__main__":
    main()
