"""Multi-chain quickstart: a K-chain ensemble on Bayesian logistic regression.

One jitted program advances all chains; cross-chain split-R-hat and ESS come
out of repro.core.stats. The run uses the adaptive masked-continuation
engine (stepping="masked" + ScheduleConfig): chains whose sequential test
stops early start their next transition inside the same compiled loop, and
each chain tunes its batch-size bucket and epsilon from its own trailing
test statistics. Compare examples/quickstart.py, which runs the same model
one chain at a time, and docs/ARCHITECTURE.md for how the pieces fit.

    python examples/multichain.py            # full-size (~minutes on CPU)
    python examples/multichain.py --smoke    # CI-sized, tens of seconds
"""
import argparse
import time

import jax
import numpy as np

from repro.core import ScheduleConfig
from repro.experiments import bayeslr


def main(smoke: bool = False):
    if smoke:
        n, d, chains, steps = 2_000, 4, 8, 200
    else:
        n, d, chains, steps = 20_000, 8, 16, 1200
    data = bayeslr.synth_mnist_like(jax.random.key(0), n_train=n, n_test=500, d=d)

    from repro.kernels import ops
    print(ops.dispatch_summary())
    print(f"BayesLR N={n}, D={d}: {chains} subsampled-MH chains x {steps} steps "
          f"(masked-continuation + adaptive scheduling)")
    t0 = time.perf_counter()
    samples, diag = bayeslr.run_posterior_ensemble(
        jax.random.key(1), data, num_chains=chains, num_steps=steps,
        batch_size=500, epsilon=0.05, sigma=0.04, overdisperse=0.2,
        stepping="masked", schedule=ScheduleConfig(),
    )
    wall = time.perf_counter() - t0

    w = samples[:, steps // 2:]  # (K, T/2, D)
    err = bayeslr.test_error(w.reshape(-1, d).mean(0),
                             np.asarray(data.x_test), np.asarray(data.y_test))
    tail = diag["rounds_tail"]
    print(f"  wall time            : {wall:.1f}s "
          f"({chains * steps / wall:.0f} transitions/sec aggregate)")
    print(f"  split R-hat (max dim): {np.max(diag['rhat']):.3f}")
    print(f"  total ESS of w[0]    : {diag['ess_w0']:.0f}")
    print(f"  acceptance per chain : {np.round(diag['accept_rate'], 2)}")
    print(f"  sections evaluated   : {diag['mean_n_evaluated_overall']:.0f} / {n} "
          f"({diag['mean_n_evaluated_overall'] / n:.1%} of data per transition)")
    print(f"  test rounds          : p50={tail['p50']:.0f} p99={tail['p99']:.0f} "
          f"max={tail['max']:.0f} (the lock-step engine would pay the max, per row)")
    print(f"  adapted epsilon      : {np.round(diag['final_epsilon'], 3)}")
    print(f"  adapted batch size   : {np.asarray(diag['final_batch_eff'], int)}")
    print(f"  posterior-mean test error: {err:.3f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (seconds instead of minutes)")
    main(smoke=ap.parse_args().smoke)
