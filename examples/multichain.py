"""Multi-chain quickstart: a K-chain ensemble on Bayesian logistic regression.

One jitted program advances all chains; cross-chain split-R-hat and ESS come
out of repro.core.stats. Compare examples/quickstart.py, which runs the same
model one chain at a time.

    PYTHONPATH=src python examples/multichain.py
"""
import time

import jax
import numpy as np

from repro.experiments import bayeslr


def main():
    n, d, chains, steps = 20_000, 8, 16, 1200
    data = bayeslr.synth_mnist_like(jax.random.key(0), n_train=n, n_test=500, d=d)

    print(f"BayesLR N={n}, D={d}: {chains} subsampled-MH chains x {steps} steps")
    t0 = time.perf_counter()
    samples, diag = bayeslr.run_posterior_ensemble(
        jax.random.key(1), data, num_chains=chains, num_steps=steps,
        batch_size=500, epsilon=0.05, sigma=0.04, overdisperse=0.2,
    )
    wall = time.perf_counter() - t0

    w = samples[:, steps // 2:]  # (K, T/2, D)
    err = bayeslr.test_error(w.reshape(-1, d).mean(0),
                             np.asarray(data.x_test), np.asarray(data.y_test))
    print(f"  wall time            : {wall:.1f}s "
          f"({chains * steps / wall:.0f} transitions/sec aggregate)")
    print(f"  split R-hat (max dim): {np.max(diag['rhat']):.3f}")
    print(f"  total ESS of w[0]    : {diag['ess_w0']:.0f}")
    print(f"  acceptance per chain : {np.round(diag['accept_rate'], 2)}")
    print(f"  sections evaluated   : {diag['mean_n_evaluated_overall']:.0f} / {n} "
          f"({diag['mean_n_evaluated_overall'] / n:.1%} of data per transition)")
    print(f"  posterior-mean test error: {err:.3f}")


if __name__ == "__main__":
    main()
