"""Quickstart: sublinear-time MH on Bayesian logistic regression.

Runs the paper's core comparison on synthetic data in ~a minute on CPU:
exact MH (O(N) per transition) vs subsampled MH (Alg. 3), plus the Sec-3.3
normality safeguard report.

    python examples/quickstart.py            # full-size (~a minute on CPU)
    python examples/quickstart.py --smoke    # CI-sized
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    RandomWalk,
    SubsampledMHConfig,
    run_chain,
    trial_run_report,
)
from repro.experiments import bayeslr


def main(smoke: bool = False):
    n, d, steps = (5_000, 10, 100) if smoke else (50_000, 50, 400)
    data = bayeslr.synth_mnist_like(jax.random.key(0), n_train=n, n_test=1000, d=d)
    target = bayeslr.make_target(data.x_train, data.y_train)
    w0 = jnp.zeros(d)
    prop = RandomWalk(0.03)

    from repro.kernels import ops
    print(ops.dispatch_summary())
    print(f"Bayesian logistic regression, N={n}, D={d} (paper Sec 4.1 scale)")
    print("\n--- Sec 3.3 safeguard (trial run) ---")
    print(trial_run_report(jax.random.key(1), w0, target, prop, num_trials=10))

    results = {}
    m = 200 if smoke else 1000
    for kernel, cfg in [
        ("exact", None),
        ("subsampled", SubsampledMHConfig(batch_size=m, epsilon=0.05, sampler="stream")),
    ]:
        t0 = time.perf_counter()
        _, samples, infos = run_chain(
            jax.random.key(2), w0, target, prop, steps, kernel=kernel, config=cfg
        )
        jax.block_until_ready(samples)
        wall = time.perf_counter() - t0
        w = np.asarray(samples)[steps // 2:]
        results[kernel] = (w, infos, wall)
        print(f"\n--- {kernel} MH ({steps} transitions) ---")
        print(f"  wall time          : {wall:.2f}s ({1e3 * wall / steps:.2f} ms/transition)")
        print(f"  posterior mean w[:4]: {w.mean(0)[:4]}")
        print(f"  acceptance rate    : {np.mean(np.asarray(infos.accepted)):.2f}")
        print(f"  sections evaluated : {np.mean(np.asarray(infos.n_evaluated)):.0f} / {n} "
              f"({np.mean(np.asarray(infos.n_evaluated)) / n:.1%})")

    we, _, te = results["exact"]
    ws, _, ts = results["subsampled"]
    print("\n--- comparison ---")
    print(f"  posterior-mean gap : {np.linalg.norm(we.mean(0) - ws.mean(0)):.4f}")
    print(f"  speedup            : {te / ts:.2f}x wall-clock at equal transitions")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (seconds instead of minutes)")
    main(smoke=ap.parse_args().smoke)
